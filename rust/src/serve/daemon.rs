//! The kernel-serving daemon: a long-running process answering
//! `get_kernel` requests over a Unix or TCP socket.
//!
//! Request flow:
//!
//! * **exact store hit** — reply immediately with the cached,
//!   NVML-measured kernel (zero measurements, zero search time);
//! * **miss** — reply immediately with the best warm guess (nearest
//!   neighbor's schedule re-legalized for the requested shape), or —
//!   when no neighbor is close enough — the **static tier**: the best
//!   of a capped, statically-ranked enumeration of the schedule space
//!   ([`crate::analysis`]), with closed-form latency/energy estimates
//!   and zero measurements. Either way a real search is enqueued on a
//!   daemon-owned [`WorkerPool`]; the finished search is written back
//!   into the sharded store, so the next request for that key is a
//!   hit. Every reply carries its `tier` (`exact`/`warm`/`static`).
//!
//! # Locking: the hot path is not serialized
//!
//! The daemon keeps TWO pieces of shared state, neither of which is a
//! request-wide lock:
//!
//! * the [`ShardedStore`] is internally synchronized **per shard**
//!   (plus a small served-LRU mutex and an `RwLock` around the
//!   neighbor index — see [`crate::store::sharded`]). An exact hit on
//!   shard A never waits behind another connection's miss refreshing
//!   shard B; an append or eviction rewrite takes only its shard;
//! * everything else (metrics, heat sketch, admission backlog, pending
//!   keys, fleet claims, the worker snapshot handle) lives behind one
//!   SMALL mutex ([`ServeState`]) that is only ever held for
//!   microseconds of bookkeeping — never across store I/O, claim I/O,
//!   lease waits, or snapshot rebuilds.
//!
//! The miss path's warm guess queries the store's incremental neighbor
//! index (candidate buckets, not an O(store) scan), so a cold-key
//! burst stays cheap even on a large store.
//!
//! # Request batching
//!
//! A `batch` frame carries N `get_kernel` requests in one socket read
//! and is answered with one positionally-matched reply frame in one
//! socket write. The daemon answers a batch in two passes: first every
//! position that needs no claim or refresh I/O (parse rejects and
//! in-memory exact hits, per-shard read locks only), then the misses
//! with their claim machinery — so an exact hit in a batch never
//! waits on a sibling miss's in-store claim file ops.
//!
//! Fleet behavior (N daemons, one store — see [`crate::fleet`]):
//!
//! * the store opens in **fleet mode**, and freshness is **push
//!   first**: a landed write-back is announced on the store's notify
//!   channel ([`crate::fleet::notify`]) and every peer's refresh loop
//!   re-reads *only the touched shard*. An interval poll (full-store
//!   refresh) remains as the fallback net — a crashed announcer can
//!   delay freshness, never wedge it. The miss path still does one
//!   targeted per-key shard refresh before claiming, so a request
//!   racing ahead of its notify is served as a hit instead of
//!   re-searched; exact hits already in memory pay NO per-request
//!   refresh I/O at all;
//! * duplicate misses coalesce at two levels — the in-memory `pending`
//!   set within one daemon, and an in-store [`InflightTable`] claim
//!   across daemons, so a key is searched **once fleet-wide**. Claims
//!   are heartbeat-renewed for the duration of the search; a crashed
//!   owner's claim expires and the key is reclaimed. Write-backs are
//!   epoch-fenced: a daemon that lost its claim mid-search has its
//!   late record rejected;
//! * a write-back that hits a busy shard lease is **parked** and
//!   retried on later writer wakeups instead of being dropped — the
//!   record is a multi-second search the fleet already paid for;
//! * when the search queue saturates, admission control
//!   ([`crate::fleet::admission`]) backlogs hot keys (pumped into
//!   freed slots in heat order) and sheds cold ones, instead of the
//!   old FIFO drop.

use super::metrics::{reply_time_s, ServeMetrics};
#[cfg(not(unix))]
use super::protocol::wire_name;
use super::protocol::{
    error_code, BatchItem, DriftHealth, HealthReply, HealthStatus, HealthTarget, KernelReply,
    MetricsReply, Reject, Request, Response, ServeSource, ServeTier, StatsReply, TraceReply,
    PROTOCOL_VERSION,
};
use crate::config::{GpuArch, SearchConfig, SearchMode};
use crate::coordinator::{EventLog, PoolEvent, SearchJob, WorkerPool};
#[cfg(not(unix))]
use crate::fleet::Stream;
use crate::fleet::{
    Backlog, HeatSketch, InflightTable, Listener, NotifyChannel, Offer, ServeAddr,
};
use crate::schedule::space::ScheduleSpace;
use crate::search::RoundStats;
use crate::store::lease::Lease;
use crate::store::transfer::{relegalize, MAX_TRANSFER_DISTANCE};
use crate::store::{
    config_fingerprint, serve_key, AppendOutcome, EvictionReport, ShardedStore, StoredKernel,
    TuningRecord, TuningStore,
};
use crate::telemetry::{
    ledger_family_index, ledger_gpu_index, LogHistogram, Span, Stage, StageTrace, TraceId,
    TraceLog, UNATTRIBUTED,
};
use crate::util::Json;
use crate::workload::Workload;
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
#[cfg(not(unix))]
use std::io::{BufRead as _, BufReader, Write as _};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Daemon configuration: where to listen (`unix:`/`tcp:`), where the
/// store lives, and the search template requests run under
/// (per-request `gpu`/`mode` overrides apply on top; the `[serve]` and
/// `[fleet]` sections set shard count, eviction quotas, pool size, and
/// fleet-coordination knobs).
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    pub addr: ServeAddr,
    pub store_dir: PathBuf,
    pub search: SearchConfig,
}

/// A queued-but-not-yet-submitted background search.
type BacklogJob = (SearchJob, Arc<TuningStore>);

/// What reserved a pending key: the wire request id (the correlator
/// every `job_*` event for the key carries) plus the distributed trace
/// the reserving miss opened — duplicate misses coalesce onto it, so a
/// key searched once fleet-wide yields exactly one trace.
#[derive(Clone)]
struct PendingMiss {
    req: String,
    trace: TraceId,
}

/// Wall-clock "now" as Unix seconds (trace timestamps).
fn unix_now_s() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Build identity the `stats` op reports: crate version, plus the git
/// hash when the build environment exported `ECOKERNEL_GIT_HASH`.
fn build_info() -> String {
    match option_env!("ECOKERNEL_GIT_HASH") {
        Some(hash) => format!("ecokernel {} ({hash})", env!("CARGO_PKG_VERSION")),
        None => format!("ecokernel {}", env!("CARGO_PKG_VERSION")),
    }
}

/// Fast-window (burn-rate) observations computed by the drift watchdog
/// at its last tick: the delta of each lifetime distribution since the
/// tick before ([`LogHistogram::delta`]). The `health` op compares
/// every `[slo]` target on BOTH windows — the lifetime (slow) window
/// catches sustained degradation, the fast window catches a fresh burn
/// the lifetime average still hides.
#[derive(Default)]
struct FastWindows {
    reply_wall: LogHistogram,
    relerr_steady: LogHistogram,
    n_requests: u64,
    n_hits: u64,
}

/// The drift watchdog's snapshot state: lifetime observations captured
/// at the previous tick (the subtrahends of the next delta) plus the
/// fast windows served to `health` until the next tick. Behind its own
/// small mutex — NEVER locked while `state` is held.
#[derive(Default)]
struct SloWindows {
    prev_reply_wall: LogHistogram,
    prev_relerr_steady: LogHistogram,
    prev_requests: usize,
    prev_hits: usize,
    /// `None` until the first watchdog tick: the fast window then
    /// equals the lifetime window (a cold daemon has no burn history).
    fast: Option<FastWindows>,
}

/// The daemon's SMALL shared state: pure bookkeeping, held only for
/// microseconds at a time. Store access never happens under this lock
/// — the [`ShardedStore`] synchronizes itself per shard.
struct ServeState {
    /// Parsed snapshot handed to background searches; rebuilt (pointer
    /// clones — records are `Arc`-shared) after every store change.
    snapshot: Arc<TuningStore>,
    /// Build ticket of the installed snapshot (see
    /// [`refresh_snapshot`]): snapshots are built OUTSIDE this lock,
    /// so an install must never roll a newer snapshot back.
    snapshot_gen: u64,
    /// Serve keys with a search queued, backlogged, running, or
    /// awaiting write-back here, mapped to the reserving miss's request
    /// id and trace id, so one request traces parse → enqueue →
    /// write-back end to end in both the event log and the trace ring.
    pending: HashMap<String, PendingMiss>,
    /// Fleet in-flight claims this daemon holds, by serve key.
    claims: HashMap<String, Lease>,
    /// Admission backlog behind a saturated search queue.
    backlog: Backlog<BacklogJob>,
    /// Decayed per-key request-rate sketch driving admission.
    heat: HeatSketch,
    metrics: ServeMetrics,
}

/// Everything a connection handler needs, shared across threads.
pub(super) struct Ctx {
    /// Internally synchronized per shard; no outer lock.
    store: ShardedStore,
    state: Mutex<ServeState>,
    /// `None` once shutdown has begun.
    pool: Mutex<Option<WorkerPool>>,
    /// Live count of jobs in the worker pool (queued or running); the
    /// stats path reads it without touching the pool mutex.
    pool_depth: Arc<AtomicUsize>,
    /// Monotonic snapshot build tickets (see [`refresh_snapshot`]).
    snapshot_epoch: AtomicU64,
    /// Set by a `shutdown` request: stop accepting connections.
    shutting: AtomicBool,
    /// Set after the drain completes: stops the claim heartbeat.
    stopped: AtomicBool,
    search: SearchConfig,
    addr: ServeAddr,
    inflight: InflightTable,
    /// The write-back push channel; `Some` in coordinated fleets with
    /// `fleet.notify` on.
    notify: Option<NotifyChannel>,
    /// Tail-sampled ring of request traces (miss chains + foreign
    /// notify-refresh continuations). Its own small mutex — NEVER
    /// locked while `state` is held, so trace bookkeeping can't extend
    /// a state-lock hold.
    traces: Mutex<TraceLog>,
    log: Option<EventLog>,
    /// When the daemon bound its socket (`stats.uptime_s`).
    started: Instant,
    /// Drift-watchdog window state (see [`SloWindows`]).
    slo: Mutex<SloWindows>,
}

impl Ctx {
    pub(super) fn is_shutting(&self) -> bool {
        self.shutting.load(Ordering::SeqCst)
    }

    pub(super) fn begin_shutdown(&self) {
        self.shutting.store(true, Ordering::SeqCst);
    }

    /// Count one `hello` negotiation (whatever was granted).
    pub(super) fn note_hello(&self) {
        self.state.lock().expect("state lock").metrics.n_hello += 1;
    }

    /// Count binary frames received on a wire-v2 connection.
    pub(super) fn note_binary_frames(&self, n: usize) {
        self.state.lock().expect("state lock").metrics.n_binary_frames += n;
    }

    /// Count one reply written out of arrival order (a fast reply that
    /// overtook an earlier slow sibling on the same connection).
    pub(super) fn note_ooo_reply(&self) {
        self.state.lock().expect("state lock").metrics.n_ooo_replies += 1;
    }
}

/// A bound, running daemon (listener open, workers + writer started).
/// Call [`Daemon::run`] to serve until shutdown.
pub struct Daemon {
    listener: Listener,
    ctx: Arc<Ctx>,
    writer: JoinHandle<()>,
    heartbeat: JoinHandle<()>,
    /// Notify-driven targeted refresh + interval poll fallback; only
    /// spawned for coordinated fleets.
    refresher: Option<JoinHandle<()>>,
    /// Cost-model drift watchdog + fast-window snapshotter; always
    /// spawned (the `health` op's burn rates need the snapshots even
    /// when re-searching is disabled).
    watchdog: JoinHandle<()>,
}

/// Handle to a daemon running on a background thread (in-process tests
/// and the fleet examples).
pub struct DaemonHandle {
    /// The resolved listen address (`tcp:...:0` becomes the real port).
    pub addr: ServeAddr,
    thread: JoinHandle<anyhow::Result<()>>,
}

impl DaemonHandle {
    /// Wait for the daemon to exit (after a `shutdown` request).
    pub fn join(self) -> anyhow::Result<()> {
        self.thread.join().map_err(|_| anyhow::anyhow!("daemon thread panicked"))?
    }
}

/// Distinguishes daemons within one process (tests spawn several), on
/// top of the pid that distinguishes processes on one host.
static DAEMON_SEQ: AtomicU64 = AtomicU64::new(0);

/// A globally-unique lease-holder id. The pid alone is NOT unique
/// across hosts or containers sharing one store volume (every
/// container's daemon can be pid 1), and two daemons with equal holder
/// strings would silently pass each other's lease checks — so a
/// startup-time nanosecond nonce disambiguates.
fn fresh_holder_id() -> String {
    let nonce = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    format!(
        "daemon-{}-{}-{nonce:016x}",
        std::process::id(),
        DAEMON_SEQ.fetch_add(1, Ordering::Relaxed)
    )
}

impl Daemon {
    /// Open the store (fleet mode), start the worker pool + write-back
    /// + heartbeat threads, and bind the listen address. Clients can
    /// connect as soon as this returns.
    pub fn bind(cfg: DaemonConfig, log: Option<EventLog>) -> anyhow::Result<Daemon> {
        cfg.search.validate().map_err(anyhow::Error::msg)?;
        let holder = fresh_holder_id();
        let fleet = &cfg.search.fleet;
        // `fleet.coordinate = false` keeps a known-single-daemon
        // deployment on the in-memory + O_APPEND fast path: no lease
        // files, no per-miss claim I/O, no per-request refresh stat.
        let store = if fleet.coordinate {
            ShardedStore::open_fleet(
                &cfg.store_dir,
                cfg.search.serve.n_shards,
                &holder,
                fleet.lease_ttl_ms,
            )?
        } else {
            ShardedStore::open(&cfg.store_dir, cfg.search.serve.n_shards)?
        };
        let snapshot = Arc::new(store.snapshot());
        let inflight = InflightTable::open(&cfg.store_dir, &holder, fleet.lease_ttl_ms)?;
        let notify = if fleet.coordinate && fleet.notify {
            Some(NotifyChannel::open(&cfg.store_dir, &holder, fleet.lease_ttl_ms)?)
        } else {
            None
        };

        let (tx, rx) = std::sync::mpsc::channel::<PoolEvent>();
        let pool =
            WorkerPool::with_sink(cfg.search.serve.n_workers, cfg.search.serve.queue_cap, tx);
        let pool_depth = pool.depth_counter();

        let (listener, addr) = Listener::bind(&cfg.addr)?;

        let ctx = Arc::new(Ctx {
            store,
            state: Mutex::new(ServeState {
                snapshot,
                snapshot_gen: 0,
                pending: HashMap::new(),
                claims: HashMap::new(),
                backlog: Backlog::new(fleet.backlog_cap),
                heat: HeatSketch::new(fleet.heat_half_life, fleet.heat_keys_cap),
                metrics: ServeMetrics::default(),
            }),
            pool: Mutex::new(Some(pool)),
            pool_depth,
            snapshot_epoch: AtomicU64::new(0),
            shutting: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            search: cfg.search,
            addr,
            inflight,
            notify,
            traces: Mutex::new(TraceLog::default()),
            log,
            started: Instant::now(),
            slo: Mutex::new(SloWindows::default()),
        });
        let writer = {
            let ctx = ctx.clone();
            std::thread::spawn(move || writer_loop(&ctx, rx))
        };
        let heartbeat = {
            let ctx = ctx.clone();
            std::thread::spawn(move || heartbeat_loop(&ctx))
        };
        let refresher = if ctx.search.fleet.coordinate {
            let ctx = ctx.clone();
            Some(std::thread::spawn(move || refresh_loop(&ctx)))
        } else {
            None
        };
        let watchdog = {
            let ctx = ctx.clone();
            std::thread::spawn(move || watchdog_loop(&ctx))
        };
        Ok(Daemon { listener, ctx, writer, heartbeat, refresher, watchdog })
    }

    /// Bind and serve on a background thread.
    pub fn spawn(cfg: DaemonConfig, log: Option<EventLog>) -> anyhow::Result<DaemonHandle> {
        let daemon = Daemon::bind(cfg, log)?;
        let addr = daemon.ctx.addr.clone();
        let thread = std::thread::spawn(move || daemon.run());
        Ok(DaemonHandle { addr, thread })
    }

    /// The resolved listen address.
    pub fn addr(&self) -> &ServeAddr {
        &self.ctx.addr
    }

    /// Serve connections until a `shutdown` request arrives, then drain
    /// the worker pool, flush write-backs, release fleet claims, and
    /// remove a Unix socket file.
    pub fn run(self) -> anyhow::Result<()> {
        // The evented data plane: nonblocking accept + `poll(2)`
        // reactors sized to cores, per-connection buffers, and a slow
        // lane for miss/batch work (see [`super::reactor`]). Platforms
        // without `poll` keep the blocking thread-per-connection loop.
        #[cfg(unix)]
        super::reactor::serve(self.listener, Arc::clone(&self.ctx));
        #[cfg(not(unix))]
        loop {
            match self.listener.accept() {
                Ok(stream) => {
                    if self.ctx.shutting.load(Ordering::SeqCst) {
                        break;
                    }
                    let ctx = self.ctx.clone();
                    std::thread::spawn(move || handle_connection(&ctx, stream));
                }
                Err(e) => {
                    if self.ctx.shutting.load(Ordering::SeqCst) {
                        break;
                    }
                    eprintln!("serve: accept failed: {e}");
                }
            }
        }
        // Drain: close the job queue, run queued searches to completion
        // (their write-backs land through the writer thread), then stop.
        // The heartbeat keeps renewing claims until the drain finishes,
        // so in-flight write-backs are not fenced out mid-shutdown.
        let pool = self.ctx.pool.lock().expect("pool lock").take();
        if let Some(pool) = pool {
            pool.finish();
        }
        let _ = self.writer.join();
        // Backlogged searches never ran: hand their keys back to the
        // fleet so another daemon's next miss claims them.
        {
            let mut state = self.ctx.state.lock().expect("state lock");
            let ServeState { backlog, claims, pending, .. } = &mut *state;
            for (key, _job) in backlog.drain() {
                pending.remove(&key);
                if let Some(lease) = claims.remove(&key) {
                    let _ = lease.release();
                }
            }
        }
        self.ctx.stopped.store(true, Ordering::SeqCst);
        let _ = self.heartbeat.join();
        if let Some(refresher) = self.refresher {
            let _ = refresher.join();
        }
        let _ = self.watchdog.join();
        #[cfg(unix)]
        if let ServeAddr::Unix(path) = &self.ctx.addr {
            let _ = std::fs::remove_file(path);
        }
        Ok(())
    }
}

/// Claim heartbeat: renew this daemon's in-flight claims at ~TTL/3 so
/// they outlive multi-second searches. Runs until the drain completes
/// (not merely until `shutdown` arrives — queued searches still need
/// their claims). A claim that fails to renew stays in the map: the
/// write-back fence rejects its record, which is the correct outcome.
fn heartbeat_loop(ctx: &Ctx) {
    let interval =
        std::time::Duration::from_millis((ctx.search.fleet.lease_ttl_ms / 3).clamp(25, 2000));
    while !ctx.stopped.load(Ordering::SeqCst) {
        std::thread::sleep(interval);
        // Renew outside the state lock — each renew is several file
        // ops and must not stall reply bookkeeping. A clone carries the
        // same (holder, epoch) identity, which is all renewal needs.
        let leases: Vec<Lease> = {
            let state = ctx.state.lock().expect("state lock");
            state.claims.values().cloned().collect()
        };
        for lease in &leases {
            let _ = lease.renew();
        }
    }
}

/// Fleet freshness loop: push first, poll as the net.
///
/// * **Notify path** — the cursor tail-reads the store's notify
///   channel every `fleet.notify_interval_ms` (one metadata stat when
///   idle) and, per delivered announcement, refreshes ONLY the touched
///   shard — O(what changed), not O(shards). Own announcements and
///   stale-epoch announcements never arrive (the cursor fences them).
/// * **Poll fallback** — every `fleet.poll_interval_ms` a full
///   [`ShardedStore::refresh`] catches anything the channel lost
///   (crashed announcer, compaction race, notify disabled). A fallback
///   pass that actually ingests changes counts as `n_poll_refresh`,
///   so a healthy push path shows `n_poll_refresh == 0`.
fn refresh_loop(ctx: &Ctx) {
    let fleet = &ctx.search.fleet;
    let mut cursor = match &ctx.notify {
        Some(channel) => match channel.cursor() {
            Ok(cursor) => Some(cursor),
            Err(e) => {
                eprintln!("serve: notify cursor failed ({e:#}); falling back to polling");
                None
            }
        },
        None => None,
    };
    // Clamp the tick so shutdown stays responsive even under a long
    // notify interval; the poll fallback keeps its own schedule.
    let tick = std::time::Duration::from_millis(fleet.notify_interval_ms.clamp(10, 1000));
    let poll_every = std::time::Duration::from_millis(fleet.poll_interval_ms);
    let mut last_poll = std::time::Instant::now();
    while !ctx.stopped.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        if let Some(cursor) = cursor.as_mut() {
            match cursor.poll() {
                Ok(events) if !events.is_empty() => {
                    // One refresh per touched shard, however many keys
                    // landed in it.
                    let shards: BTreeSet<usize> = events.iter().map(|e| e.shard).collect();
                    let mut refreshed: BTreeMap<usize, f64> = BTreeMap::new();
                    let mut changed = 0usize;
                    for &shard in &shards {
                        let t = Instant::now();
                        match ctx.store.refresh_shard(shard) {
                            Ok(n) => {
                                changed += n;
                                refreshed.insert(shard, t.elapsed().as_secs_f64());
                            }
                            Err(e) => {
                                eprintln!("serve: notify refresh of shard {shard} failed: {e:#}")
                            }
                        }
                    }
                    if changed > 0 {
                        refresh_snapshot(ctx);
                    }
                    // Count only announcements whose shard refresh
                    // SUCCEEDED — the stat is the push path's health
                    // signal, and a daemon whose refreshes all fail is
                    // not fresh no matter how many events it read.
                    let acted =
                        events.iter().filter(|e| refreshed.contains_key(&e.shard)).count();
                    {
                        let mut state = ctx.state.lock().expect("state lock");
                        state.metrics.n_notify_refresh += acted;
                    }
                    // Close the fleet-wide chain: an announcement that
                    // carries its originating miss's trace id lands a
                    // `notify_refresh` continuation here, under the
                    // SAME id — `query --trace` on this peer shows the
                    // foreign search's write-back reaching it.
                    let mut traces = ctx.traces.lock().expect("traces lock");
                    for e in &events {
                        let Some(tid) = e.trace_id() else { continue };
                        let Some(&secs) = refreshed.get(&e.shard) else { continue };
                        traces.record_remote(
                            tid,
                            &e.key,
                            unix_now_s() - secs,
                            Span::new("notify_refresh", 0.0, secs).with_note(&e.holder),
                        );
                    }
                }
                Ok(_) => {}
                Err(e) => eprintln!("serve: notify poll failed: {e:#}"),
            }
        }
        if last_poll.elapsed() >= poll_every {
            last_poll = std::time::Instant::now();
            match ctx.store.refresh() {
                Ok(changed) if changed > 0 => {
                    refresh_snapshot(ctx);
                    ctx.state.lock().expect("state lock").metrics.n_poll_refresh += 1;
                }
                Ok(_) => {}
                Err(e) => eprintln!("serve: poll refresh failed: {e:#}"),
            }
        }
    }
}

/// Rebuild the worker snapshot (pointer clones) and install it —
/// unless a NEWER build landed first. Builds run outside the state
/// lock, so two concurrent rebuilders (a miss's refresh and the writer
/// thread) can finish out of order; the ticket is taken BEFORE the
/// store is read, so a build that began after another's store change
/// always carries the higher ticket and an install can never roll the
/// snapshot back to one missing a just-written record.
fn refresh_snapshot(ctx: &Ctx) {
    let gen = ctx.snapshot_epoch.fetch_add(1, Ordering::SeqCst) + 1;
    let snapshot = Arc::new(ctx.store.snapshot());
    let mut state = ctx.state.lock().expect("state lock");
    if gen > state.snapshot_gen {
        state.snapshot = snapshot;
        state.snapshot_gen = gen;
    }
}

/// [`crate::serve::MODEL_REGIMES`] index of the steady regime (every
/// round after round 0) — the window the drift verdict watches.
const STEADY_REGIME: usize = 1;

/// Cost-model drift watchdog, on the `slo.drift_interval_ms` cadence.
/// Each tick:
///
/// 1. snapshots the lifetime reply-wall / hit-rate / steady-relerr
///    observations and installs their deltas ([`LogHistogram::delta`])
///    as the fast (burn-rate) windows the `health` op evaluates;
/// 2. when the steady-regime mean relative energy error sits past
///    `slo.relerr_ceiling` (with `slo.min_window` samples behind it),
///    emits a `model_drift` event and admits up to `slo.drift_budget`
///    re-searches of the hottest stored keys — through the normal
///    pending/claim reservation, into FREE worker-queue slots only, so
///    a drifting model can never starve real misses.
fn watchdog_loop(ctx: &Ctx) {
    let slo = &ctx.search.slo;
    let interval = std::time::Duration::from_millis(slo.drift_interval_ms);
    // Short sleep tick so shutdown stays responsive under a long
    // watchdog interval (same pattern as the refresh loop).
    let tick = std::time::Duration::from_millis(slo.drift_interval_ms.clamp(10, 250));
    let mut last = Instant::now();
    while !ctx.stopped.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        if last.elapsed() < interval {
            continue;
        }
        last = Instant::now();
        watchdog_tick(ctx);
    }
}

/// One watchdog pass: fast-window snapshot, then the drift verdict.
fn watchdog_tick(ctx: &Ctx) {
    let slo_cfg = &ctx.search.slo;
    // Lifetime observations under one short state-lock hold. The
    // clones are fixed arrays — memcpy, no heap.
    let (reply_wall, relerr_steady, n_requests, n_hits) = {
        let state = ctx.state.lock().expect("state lock");
        (
            state.metrics.reply_wall().clone(),
            state.metrics.model_energy_relerr(STEADY_REGIME).clone(),
            state.metrics.n_requests,
            state.metrics.n_hits,
        )
    };
    {
        let mut slo = ctx.slo.lock().expect("slo lock");
        slo.fast = Some(FastWindows {
            reply_wall: reply_wall.delta(&slo.prev_reply_wall),
            relerr_steady: relerr_steady.delta(&slo.prev_relerr_steady),
            n_requests: n_requests.saturating_sub(slo.prev_requests) as u64,
            n_hits: n_hits.saturating_sub(slo.prev_hits) as u64,
        });
        slo.prev_reply_wall = reply_wall;
        slo.prev_relerr_steady = relerr_steady.clone();
        slo.prev_requests = n_requests;
        slo.prev_hits = n_hits;
    }
    let drifting = slo_cfg.relerr_ceiling > 0.0
        && relerr_steady.count() >= slo_cfg.min_window
        && relerr_steady.mean() > slo_cfg.relerr_ceiling;
    if !drifting {
        return;
    }
    let admitted = if slo_cfg.drift_budget > 0 { admit_drift_researches(ctx) } else { 0 };
    if let Some(log) = &ctx.log {
        log.emit(
            "model_drift",
            vec![
                ("relerr_steady_mean", Json::num(relerr_steady.mean())),
                ("ceiling", Json::num(slo_cfg.relerr_ceiling)),
                ("admitted", Json::num(admitted as f64)),
                ("budget", Json::num(slo_cfg.drift_budget as f64)),
            ],
        );
    }
}

/// Re-search the hottest stored keys after a drift verdict: up to
/// `slo.drift_budget` jobs per interval, each reserved through the
/// normal pending/claim machinery so local duplicates and fleet peers
/// coalesce on it. Jobs are submitted WITHOUT a store snapshot — an
/// exact-hit replay would hand back the very record whose model
/// drifted — and only into free worker-queue slots; the heat-ordered
/// backlog stays reserved for real misses.
fn admit_drift_researches(ctx: &Ctx) -> usize {
    let budget = ctx.search.slo.drift_budget;
    // Over-fetch the heat ranking so pending and foreign-claimed keys
    // don't exhaust the shortlist before the budget is met.
    let (hottest, snapshot) = {
        let state = ctx.state.lock().expect("state lock");
        (state.heat.hottest(budget * 4 + 16), state.snapshot.clone())
    };
    // A re-search needs a workload to run: index the snapshot's
    // records by serve key (cold path, once per drifting interval).
    let by_key: HashMap<String, &Arc<TuningRecord>> = snapshot
        .records()
        .iter()
        .map(|rec| (serve_key(&rec.workload_id, &rec.gpu, &rec.mode, &rec.fingerprint), rec))
        .collect();
    let mut admitted = 0usize;
    for (key, _heat) in &hottest {
        if admitted >= budget {
            break;
        }
        let Some(rec) = by_key.get(key) else { continue };
        let cfg = request_cfg(ctx, GpuArch::parse(&rec.gpu), SearchMode::parse(&rec.mode));
        let mut state = ctx.state.lock().expect("state lock");
        if state.pending.contains_key(key) {
            continue;
        }
        if ctx.search.fleet.coordinate {
            // Fleet claim outside the state lock, mirroring the miss
            // path — claim I/O must not stall reply bookkeeping.
            drop(state);
            let attempt = ctx.inflight.claim(key);
            state = ctx.state.lock().expect("state lock");
            match attempt {
                Ok(Some(lease)) => {
                    let raced = state.pending.contains_key(key);
                    let newest = match state.claims.get(key) {
                        Some(held) => lease.epoch() > held.epoch(),
                        None => true,
                    };
                    if newest {
                        state.claims.insert(key.clone(), lease);
                    }
                    if raced {
                        continue; // a real miss reserved it meanwhile
                    }
                }
                Ok(None) => continue, // a peer is already searching it
                Err(_) => continue,   // claim I/O failed: retry next tick
            }
        }
        let tid = TraceId::mint();
        let req = format!("drift-{}", tid.to_hex());
        state.pending.insert(key.clone(), PendingMiss { req: req.clone(), trace: tid });
        state.metrics.n_enqueued += 1;
        drop(state);
        let job = SearchJob { name: key.clone(), workload: rec.workload, cfg };
        let submitted = {
            let mut pool = ctx.pool.lock().expect("pool lock");
            match pool.as_mut() {
                Some(p) => p.try_submit_with_snapshot(job, None),
                None => false, // shutting down
            }
        };
        if submitted {
            admitted += 1;
            {
                let mut state = ctx.state.lock().expect("state lock");
                state.metrics.n_drift_researches += 1;
            }
            {
                let mut traces = ctx.traces.lock().expect("traces lock");
                traces.open(tid, key, &req, unix_now_s());
            }
            if let Some(log) = &ctx.log {
                log.emit_traced(
                    "job_enqueued",
                    &req,
                    vec![("key", Json::str(key.clone())), ("via", Json::str("drift"))],
                );
            }
        } else {
            // Queue full (or shutting down): undo the reservation —
            // drift work never takes backlog slots from real misses —
            // and stop; later keys won't fit either.
            let released = {
                let mut state = ctx.state.lock().expect("state lock");
                state.pending.remove(key);
                state.metrics.n_enqueued -= 1;
                state.claims.remove(key)
            };
            if let Some(lease) = released {
                let _ = lease.release();
            }
            break;
        }
    }
    admitted
}

/// Which direction breaches a threshold.
#[derive(Clone, Copy)]
enum Breach {
    /// Observations above the threshold breach (ceilings).
    Above,
    /// Observations below the threshold breach (floors).
    Below,
}

/// Evaluate one windowed target: `(value, samples)` on the slow
/// (lifetime) and fast (burn-rate) windows against a threshold. Both
/// windows breached = `critical`; one = `warn`; a window under
/// `min_window` samples never breaches, and a zero threshold disables
/// the target.
fn windowed_target(
    name: &str,
    threshold: f64,
    dir: Breach,
    slow: (f64, u64),
    fast: (f64, u64),
    min_window: u64,
) -> HealthTarget {
    let (value, slow_n) = slow;
    let (fast_value, fast_n) = fast;
    let breached = |v: f64| match dir {
        Breach::Above => v > threshold,
        Breach::Below => v < threshold,
    };
    let word = match dir {
        Breach::Above => "over",
        Breach::Below => "under",
    };
    let (status, reason) = if threshold == 0.0 {
        (HealthStatus::Ok, "disabled (threshold 0)".to_string())
    } else {
        let slow_breach = slow_n >= min_window && breached(value);
        let fast_breach = fast_n >= min_window && breached(fast_value);
        match (slow_breach, fast_breach) {
            (true, true) => (
                HealthStatus::Critical,
                format!(
                    "fast and slow windows {word} {threshold}: {fast_value:.4} / {value:.4}"
                ),
            ),
            (true, false) => {
                (HealthStatus::Warn, format!("slow window {word} {threshold}: {value:.4}"))
            }
            (false, true) => {
                (HealthStatus::Warn, format!("fast window {word} {threshold}: {fast_value:.4}"))
            }
            (false, false) if slow_n < min_window && fast_n < min_window => {
                (HealthStatus::Ok, format!("warming up ({slow_n}/{min_window} samples)"))
            }
            (false, false) => (HealthStatus::Ok, "within target".to_string()),
        }
    };
    HealthTarget { name: name.to_string(), status, reason, value, fast_value, threshold }
}

/// The backlog gauge: instantaneous depth vs its ceiling — `critical`
/// past the ceiling, `warn` past half of it, disabled at 0. No
/// windows: a deep backlog is actionable the moment it exists.
fn backlog_target(len: usize, ceiling: usize) -> HealthTarget {
    let (status, reason) = if ceiling == 0 {
        (HealthStatus::Ok, "disabled (threshold 0)".to_string())
    } else if len > ceiling {
        (HealthStatus::Critical, format!("backlog {len} over ceiling {ceiling}"))
    } else if len > ceiling / 2 {
        (HealthStatus::Warn, format!("backlog {len} over half the ceiling {ceiling}"))
    } else {
        (HealthStatus::Ok, "within target".to_string())
    };
    HealthTarget {
        name: "backlog".to_string(),
        status,
        reason,
        value: len as f64,
        fast_value: len as f64,
        threshold: ceiling as f64,
    }
}

/// Answer a `health` frame: every `[slo]` target evaluated on the
/// lifetime (slow) window and the watchdog's fast window, plus the
/// drift watchdog's state. Before the first watchdog tick the fast
/// window IS the lifetime window (a cold daemon has no burn history).
fn health_reply(ctx: &Ctx, id: String) -> HealthReply {
    let slo = &ctx.search.slo;
    let (reply_wall, relerr_steady, n_requests, n_hits, backlog_len, n_drift) = {
        let state = ctx.state.lock().expect("state lock");
        (
            state.metrics.reply_wall().clone(),
            state.metrics.model_energy_relerr(STEADY_REGIME).clone(),
            state.metrics.n_requests,
            state.metrics.n_hits,
            state.backlog.len(),
            state.metrics.n_drift_researches,
        )
    };
    let (fast_wall, fast_relerr, fast_requests, fast_hits) = {
        let windows = ctx.slo.lock().expect("slo lock");
        match &windows.fast {
            Some(f) => (f.reply_wall.clone(), f.relerr_steady.clone(), f.n_requests, f.n_hits),
            None => {
                (reply_wall.clone(), relerr_steady.clone(), n_requests as u64, n_hits as u64)
            }
        }
    };
    let rate = |hits: u64, reqs: u64| if reqs == 0 { 0.0 } else { hits as f64 / reqs as f64 };
    let min = slo.min_window;
    let targets = vec![
        windowed_target(
            "p99_reply_wall_s",
            slo.p99_reply_wall_s,
            Breach::Above,
            (reply_wall.quantile(99.0), reply_wall.count()),
            (fast_wall.quantile(99.0), fast_wall.count()),
            min,
        ),
        windowed_target(
            "hit_rate",
            slo.hit_rate_floor,
            Breach::Below,
            (rate(n_hits as u64, n_requests as u64), n_requests as u64),
            (rate(fast_hits, fast_requests), fast_requests),
            min,
        ),
        windowed_target(
            "relerr_steady",
            slo.relerr_ceiling,
            Breach::Above,
            (relerr_steady.mean(), relerr_steady.count()),
            (fast_relerr.mean(), fast_relerr.count()),
            min,
        ),
        backlog_target(backlog_len, slo.backlog_ceiling),
    ];
    let status = targets.iter().fold(HealthStatus::Ok, |acc, t| acc.worst(t.status));
    let drifting = slo.relerr_ceiling > 0.0
        && relerr_steady.count() >= min
        && relerr_steady.mean() > slo.relerr_ceiling;
    HealthReply {
        id,
        status,
        targets,
        drift: DriftHealth {
            n_drift_researches: n_drift as u64,
            relerr_steady_mean: relerr_steady.mean(),
            relerr_fast_mean: fast_relerr.mean(),
            budget: slo.drift_budget,
            drifting,
        },
    }
}

/// How a finished search's write-back ended.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Landing {
    /// Appended to the store.
    Accepted,
    /// Rejected by the epoch fence (another daemon owns the key now).
    Fenced,
    /// Given up for good (lease never freed, or an I/O error).
    Dropped,
}

impl Landing {
    fn name(self) -> &'static str {
        match self {
            Landing::Accepted => "accepted",
            Landing::Fenced => "fenced",
            Landing::Dropped => "dropped",
        }
    }
}

/// A finished search waiting to be written back. Parked (and retried
/// on later writer wakeups) while its shard's lease is held by another
/// fleet member — the old behavior of dropping the record after a few
/// inline retries threw away a multi-second search the fleet had
/// already paid for.
struct PendingWriteback {
    rec: TuningRecord,
    key: String,
    n_measurements: usize,
    /// NVML joules the search burned across its measured pool — the
    /// ledger's `paid` side, debited when the write-back lands.
    measurement_joules: f64,
    sim_time_s: f64,
    /// Per-round search stats, carried through to the terminal landing:
    /// each round becomes a `search_round` span on the miss's trace
    /// (snr/k/relerr attrs riding along) and feeds the model-accuracy
    /// histograms exactly once.
    rounds: Vec<RoundStats>,
    attempts: usize,
    /// When the first attempt ran. The drop budget is wall-clock, not
    /// attempt-count: parked jobs are re-offered on EVERY writer wakeup
    /// (each pool event included), so under a completion burst an
    /// attempt counter would burn out in milliseconds.
    first_attempt: Option<std::time::Instant>,
}

/// Park retry cadence, and the wall-clock budget after which a
/// write-back is dropped for good (a foreign lease never freeing for
/// this long = a wedged peer).
const PARK_RETRY_MS: u64 = 250;
const PARK_BUDGET: std::time::Duration = std::time::Duration::from_secs(30);

/// Write-back thread: append every finished search to the sharded
/// store (epoch-fenced by its fleet claim), emit the eviction audit,
/// refresh the worker snapshot, and pump the admission backlog into
/// the freed queue slot. A failed (panicked) search releases its
/// reservations so the next request for that key can retry instead of
/// coalescing into a dead search forever. Lease-busy write-backs are
/// parked and retried; `n_searches_done` / `measurements_paid` count
/// only write-backs that actually landed.
fn writer_loop(ctx: &Ctx, rx: Receiver<PoolEvent>) {
    let mut parked: Vec<PendingWriteback> = Vec::new();
    loop {
        // Block on the next finished search; with parked write-backs
        // waiting, wake periodically to retry them.
        let event = if parked.is_empty() {
            match rx.recv() {
                Ok(e) => Some(e),
                Err(_) => break, // pool finished (shutdown drain)
            }
        } else {
            match rx.recv_timeout(std::time::Duration::from_millis(PARK_RETRY_MS)) {
                Ok(e) => Some(e),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        };
        match event {
            Some(PoolEvent::Done(result)) => {
                let rec = TuningRecord::from_outcome(&result.outcome, &result.cfg);
                let key = serve_key(&rec.workload_id, &rec.gpu, &rec.mode, &rec.fingerprint);
                let job = PendingWriteback {
                    key,
                    n_measurements: result.outcome.n_energy_measurements(),
                    measurement_joules: result
                        .outcome
                        .measured_pool
                        .iter()
                        .filter(|e| e.energy_measured)
                        .map(|e| e.energy_j)
                        .sum(),
                    sim_time_s: result.outcome.clock.total_s,
                    rounds: result.outcome.rounds.clone(),
                    attempts: 0,
                    first_attempt: None,
                    rec,
                };
                if let Some(job) = land_writeback(ctx, job) {
                    // The worker that produced this result freed a
                    // queue slot even though its write-back is parked:
                    // refill the slot from the backlog now, not when
                    // the parked record terminally lands.
                    parked.push(job);
                    pump_backlog(ctx);
                }
            }
            Some(PoolEvent::Failed { name, cfg, workload, error, .. }) => {
                let key = serve_key(
                    &workload.id(),
                    cfg.gpu.name(),
                    cfg.mode.name(),
                    &config_fingerprint(&cfg),
                );
                eprintln!("serve: background search '{name}' failed: {error}");
                let pending = {
                    let mut state = ctx.state.lock().expect("state lock");
                    let p = state.pending.remove(&key);
                    if let Some(lease) = state.claims.remove(&key) {
                        let _ = lease.release();
                    }
                    p
                };
                // A failed search is exactly what tail-sampling must
                // keep: terminal error span, errored close.
                if let Some(p) = pending {
                    let mut traces = ctx.traces.lock().expect("traces lock");
                    if let Some(start) = traces.start_unix_s(p.trace) {
                        let off = (unix_now_s() - start).max(0.0);
                        let span = Span::new("search_failed", off, 0.0).with_note(&error);
                        traces.span(p.trace, span);
                    }
                    traces.close(p.trace, true);
                }
                if let Some(log) = &ctx.log {
                    log.emit(
                        "job_search_failed",
                        vec![("key", Json::str(key)), ("error", Json::str(error))],
                    );
                }
                pump_backlog(ctx);
            }
            None => {}
        }
        // Re-offer every parked write-back on each wakeup.
        let waiting = std::mem::take(&mut parked);
        for job in waiting {
            if let Some(job) = land_writeback(ctx, job) {
                parked.push(job);
            }
        }
    }
    // Shutdown drain: nothing submits anymore — give each parked
    // record one final blocking attempt (waits out the lease ~0.5 s)
    // before the daemon exits.
    for job in parked {
        let claim = ctx.state.lock().expect("state lock").claims.get(&job.key).cloned();
        let landing = match &claim {
            Some(lease) => match ctx.store.append_claimed(job.rec.clone(), lease) {
                Ok(true) => Landing::Accepted,
                Ok(false) => Landing::Fenced,
                Err(e) => {
                    eprintln!("serve: final write-back for {} failed: {e:#}", job.key);
                    Landing::Dropped
                }
            },
            None => match ctx.store.append(job.rec.clone()) {
                Ok(()) => Landing::Accepted,
                Err(e) => {
                    eprintln!("serve: final write-back for {} failed: {e:#}", job.key);
                    Landing::Dropped
                }
            },
        };
        finish_writeback(ctx, &job, landing);
    }
}

/// One write-back attempt. Returns the job when it stays parked
/// (lease busy, retry budget left); `None` once it reached a terminal
/// landing. No daemon lock is held across the store call.
fn land_writeback(ctx: &Ctx, mut job: PendingWriteback) -> Option<PendingWriteback> {
    job.attempts += 1;
    let first_attempt = *job.first_attempt.get_or_insert_with(std::time::Instant::now);
    // The newest claim for this key fences the append; fetched fresh
    // on every retry (a concurrent re-claim bumps the epoch).
    let claim = ctx.state.lock().expect("state lock").claims.get(&job.key).cloned();
    let outcome = match &claim {
        Some(lease) => ctx.store.try_append_claimed(job.rec.clone(), lease),
        None => ctx.store.try_append(job.rec.clone()),
    };
    match outcome {
        Ok(AppendOutcome::Appended) => {
            finish_writeback(ctx, &job, Landing::Accepted);
            None
        }
        Ok(AppendOutcome::FencedOut) => {
            eprintln!(
                "serve: write-back for {} rejected (stale fleet claim — another daemon \
                 reclaimed the key)",
                job.key
            );
            finish_writeback(ctx, &job, Landing::Fenced);
            None
        }
        Ok(AppendOutcome::LeaseBusy) => {
            if first_attempt.elapsed() >= PARK_BUDGET {
                eprintln!(
                    "serve: write-back for {} dropped after {} retries over {:?} (shard lease \
                     never freed)",
                    job.key,
                    job.attempts,
                    first_attempt.elapsed()
                );
                finish_writeback(ctx, &job, Landing::Dropped);
                return None;
            }
            if job.attempts == 1 {
                if let Some(log) = &ctx.log {
                    log.emit("job_writeback_parked", vec![("key", Json::str(job.key.clone()))]);
                }
            }
            Some(job)
        }
        Err(e) => {
            eprintln!("serve: write-back failed for {}: {e:#}", job.key);
            finish_writeback(ctx, &job, Landing::Dropped);
            None
        }
    }
}

/// Terminal write-back bookkeeping: eviction (on an accepted append),
/// metrics — counted as "done" ONLY when the record landed — snapshot
/// refresh, pending/claim release, audit events, and a backlog pump
/// for the freed worker slot.
fn finish_writeback(ctx: &Ctx, job: &PendingWriteback, landing: Landing) {
    let accepted = landing == Landing::Accepted;
    let mut evict = EvictionReport::default();
    if accepted {
        let serve = &ctx.search.serve;
        match ctx.store.enforce_limits(serve.per_gpu_quota, serve.max_records) {
            Ok(report) => evict = report,
            Err(e) => eprintln!("serve: eviction failed: {e:#}"),
        }
    }
    // Rebuild the worker snapshot (pointer clones) BEFORE taking the
    // small lock — never store work under it.
    if accepted {
        refresh_snapshot(ctx);
    }
    // Ledger debit indices for an accepted landing (cold path, but the
    // lookups are plain `&str` compares anyway).
    let paid_cell = ledger_gpu_index(&job.rec.gpu)
        .map(|gpu| (gpu, ledger_family_index(job.rec.workload.family())));
    let (claim, pending) = {
        let mut state = ctx.state.lock().expect("state lock");
        match landing {
            Landing::Accepted => {
                state.metrics.n_searches_done += 1;
                state.metrics.measurements_paid += job.n_measurements;
                state.metrics.n_evicted_records += evict.n_evicted;
                if let Some((gpu, family)) = paid_cell {
                    state.metrics.ledger.record_paid(gpu, family, job.measurement_joules);
                }
            }
            Landing::Fenced => state.metrics.n_writebacks_fenced += 1,
            Landing::Dropped => state.metrics.n_writebacks_dropped += 1,
        }
        // Model-accuracy telemetry: every search this daemon ran paid
        // its rounds, whatever the landing — record snr/relerr/k per
        // regime exactly once, at the terminal landing.
        for r in &job.rounds {
            state.metrics.record_model_round(r);
        }
        let pending = state.pending.remove(&job.key);
        (state.claims.remove(&job.key), pending)
    };
    // Close the trace: one span per search round (model attrs riding
    // along), then the write-back with its landing. The write-back
    // span covers first attempt → terminal landing (parked time
    // included — that wait is exactly what the trace should surface);
    // rounds are laid out to END where the write-back begins, their
    // relative durations from the search's own clock.
    if let Some(p) = &pending {
        let mut traces = ctx.traces.lock().expect("traces lock");
        if let Some(start) = traces.start_unix_s(p.trace) {
            let wb_dur = job.first_attempt.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
            let now = (unix_now_s() - start).max(0.0);
            let wb_start = (now - wb_dur).max(0.0);
            let search_s = job.rounds.last().map(|r| r.elapsed_s).unwrap_or(0.0);
            let search_start = (wb_start - search_s).max(0.0);
            let mut cum = 0.0;
            for r in &job.rounds {
                let dur = (r.elapsed_s - cum).max(0.0);
                let mut span = Span::new("search_round", search_start + cum, dur);
                span.round = Some(r.round);
                span.snr_db = r.snr_db;
                span.relerr = r.relerr;
                span.k = (r.k > 0.0).then_some(r.k);
                span.n_measured = Some(r.n_measured);
                traces.span(p.trace, span);
                cum = r.elapsed_s;
            }
            let wb = Span::new("writeback", wb_start, wb_dur).with_note(landing.name());
            traces.span(p.trace, wb);
        }
        traces.close(p.trace, landing == Landing::Dropped);
    }
    // Push path: announce the landed record (with the claim epoch it
    // landed under, for the receivers' stale-epoch fence) BEFORE the
    // claim is released — peers wake and refresh only this shard. A
    // failed announce only defers to their poll fallback. The
    // originating trace id rides along, so the receivers' refresh
    // continues the chain under the same id.
    if accepted {
        if let Some(notify) = &ctx.notify {
            let epoch = claim.as_ref().map(|lease| lease.epoch()).unwrap_or(0);
            let shard = ctx.store.shard_of(&job.key);
            let trace = pending.as_ref().map(|p| p.trace);
            if let Err(e) = notify.announce(&job.key, shard, epoch, trace) {
                eprintln!("serve: notify announce failed for {}: {e:#}", job.key);
            }
        }
    }
    // Released only now — after the record is durably appended — so
    // another daemon's claim can never race ahead of the data.
    if let Some(lease) = claim {
        let _ = lease.release();
    }
    if let Some(log) = &ctx.log {
        log.emit_traced(
            "job_search_done",
            pending.as_ref().map(|p| p.req.as_str()).unwrap_or(""),
            vec![
                ("key", Json::str(job.key.clone())),
                ("n_energy_measurements", Json::num(job.n_measurements as f64)),
                ("sim_time_s", Json::num(job.sim_time_s)),
                ("evicted_records", Json::num(evict.n_evicted as f64)),
                ("accepted", Json::Bool(accepted)),
                ("landing", Json::str(landing.name())),
            ],
        );
        for victim in &evict.victims {
            log.emit(
                "job_evicted",
                vec![
                    ("key", Json::str(victim.key.clone())),
                    ("reason", Json::str(victim.reason)),
                    ("shard", Json::num(victim.shard as f64)),
                    ("records", Json::num(victim.n_records as f64)),
                ],
            );
        }
    }
    pump_backlog(ctx);
}

/// Move backlogged searches into the worker queue, hottest first,
/// until the queue refuses or the backlog empties.
fn pump_backlog(ctx: &Ctx) {
    loop {
        let popped = {
            let mut state = ctx.state.lock().expect("state lock");
            let ServeState { backlog, heat, pending, .. } = &mut *state;
            backlog.pop_hottest(heat).map(|(key, job)| {
                let req = pending.get(&key).map(|p| p.req.clone()).unwrap_or_default();
                (key, job, req)
            })
        };
        let Some((key, (job, snapshot), req)) = popped else { return };
        let submitted = {
            let mut pool = ctx.pool.lock().expect("pool lock");
            match pool.as_mut() {
                Some(p) => p.try_submit_with_snapshot(job.clone(), Some(snapshot.clone())),
                None => false, // shutting down: run() releases the claims
            }
        };
        if submitted {
            if let Some(log) = &ctx.log {
                log.emit_traced(
                    "job_enqueued",
                    &req,
                    vec![("key", Json::str(key)), ("via", Json::str("backlog"))],
                );
            }
        } else {
            // Hand the slot back. The backlog may have refilled while
            // the submit was attempted: restore competes by heat and
            // sheds the coldest entry instead of growing past its cap.
            let shed: Option<(String, Option<PendingMiss>)> = {
                let mut state = ctx.state.lock().expect("state lock");
                let ServeState { backlog, heat, pending, claims, metrics, .. } = &mut *state;
                match backlog.restore(key, (job, snapshot), heat) {
                    Offer::Queued => None,
                    Offer::Displaced { key: shed_key, .. }
                    | Offer::Rejected { key: shed_key, .. } => {
                        let p = pending.remove(&shed_key);
                        metrics.n_enqueued -= 1;
                        metrics.n_shed += 1;
                        if let Some(lease) = claims.remove(&shed_key) {
                            let _ = lease.release();
                        }
                        Some((shed_key, p))
                    }
                }
            };
            if let Some((shed_key, p)) = shed {
                close_shed_trace(ctx, p.as_ref(), "restore_overflow");
                if let Some(log) = &ctx.log {
                    log.emit(
                        "job_shed",
                        vec![
                            ("key", Json::str(shed_key)),
                            ("reason", Json::str("restore_overflow")),
                        ],
                    );
                }
            }
            return;
        }
    }
}

/// Close a shed key's trace: admission dropped its search, which is a
/// terminal (non-error) outcome — one `shed` span carrying the reason.
/// Called AFTER the state lock is released, never under it.
fn close_shed_trace(ctx: &Ctx, pending: Option<&PendingMiss>, reason: &str) {
    let Some(p) = pending else { return };
    let mut traces = ctx.traces.lock().expect("traces lock");
    if let Some(start) = traces.start_unix_s(p.trace) {
        let off = (unix_now_s() - start).max(0.0);
        traces.span(p.trace, Span::new("shed", off, 0.0).with_note(reason));
    }
    traces.close(p.trace, false);
}

/// One connection: serve frames until the client disconnects (or asks
/// for shutdown).
/// The blocking fallback connection handler (non-unix platforms,
/// where the `poll(2)` reactor is unavailable): line-JSON only, one
/// thread per connection, strictly in-order replies.
#[cfg(not(unix))]
fn handle_connection(ctx: &Ctx, stream: Stream) {
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(e) => {
            eprintln!("serve: connection clone failed: {e}");
            return;
        }
    };
    let mut out = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // client gone
        };
        if line.trim().is_empty() {
            continue;
        }
        let (frame, shutdown, traced, opened) = handle_frame(ctx, &line);
        let t_write = Instant::now();
        if writeln!(out, "{frame}").is_err() {
            break;
        }
        let _ = out.flush();
        if traced {
            note_reply_write(ctx, opened, t_write.elapsed().as_secs_f64());
        }
        if shutdown {
            ctx.shutting.store(true, Ordering::SeqCst);
            // Wake the accept loop with a throwaway connection.
            let _ = Stream::connect(&ctx.addr);
            break;
        }
    }
}

/// Record the reply-write stage for one traced reply, after its bytes
/// left (or at least entered the socket buffer): the stage-histogram
/// record, plus — when this frame opened a distributed trace (it was
/// the RESERVING miss) — the same measurement as a `reply_write` span
/// on that trace. One short state-lock reacquisition, then the trace
/// lock, never both at once.
pub(super) fn note_reply_write(ctx: &Ctx, opened: Option<TraceId>, secs: f64) {
    ctx.state.lock().expect("state lock").metrics.record_stage(Stage::ReplyWrite, secs);
    if let Some(tid) = opened {
        let mut traces = ctx.traces.lock().expect("traces lock");
        if let Some(start) = traces.start_unix_s(tid) {
            let off = (unix_now_s() - start - secs).max(0.0);
            traces.span(tid, Span::new("reply_write", off, secs));
        }
    }
}

/// Wall-clock context of one in-flight kernel request: the receipt
/// instant plus its stage trace. Stack-only — `Copy` arrays, no heap —
/// so threading it down the serve call chain costs nothing.
#[derive(Clone, Copy)]
struct ReqTrace {
    start: Instant,
    stages: StageTrace,
    /// Client-supplied trace id from the wire, when the frame carried
    /// one; the reserve point mints a fresh id when absent.
    wire: Option<TraceId>,
    /// Set once this request opened a distributed trace (it was the
    /// RESERVING miss) — the connection loop attaches the reply-write
    /// span to it after the bytes leave.
    opened: Option<TraceId>,
}

impl ReqTrace {
    fn begin(start: Instant) -> ReqTrace {
        ReqTrace { start, stages: StageTrace::new(), wire: None, opened: None }
    }
}

/// Dispatch one request frame; returns (response frame, shutdown?,
/// kernel-serving frame? — only those record the reply-write stage,
/// trace opened by this frame — it gets the reply-write span too).
/// Only the blocking non-unix loop uses this; the reactor drives
/// [`dispatch_fast`]/[`run_slow`] directly so it can interleave.
#[cfg(not(unix))]
fn handle_frame(ctx: &Ctx, line: &str) -> (Json, bool, bool, Option<TraceId>) {
    match dispatch_fast(ctx, line) {
        FrameAction::Reply(frame, shutdown, traced, opened) => (frame, shutdown, traced, opened),
        // This strictly-in-order entry point cannot switch framing
        // mid-stream, so it declines binary by acking `line` — the
        // negotiation contract explicitly allows the daemon to grant
        // less than was asked.
        FrameAction::Hello { id, .. } => (
            Response::HelloAck { id, wire: wire_name::LINE.to_string() }.to_json(),
            false,
            false,
            None,
        ),
        FrameAction::Slow(job) => {
            let (body, opened) = run_slow(ctx, job);
            (body.into_json(), false, true, opened)
        }
    }
}

/// What one parsed frame needs from the transport loop. The fast path
/// — rejects, admin ops, and `get_kernel` whose per-shard memory probe
/// hits — is answered inline on the calling (reactor) thread in
/// microseconds. Claim/refresh I/O and batch fan-out go to the slow
/// lane so they can never stall a sibling connection's hits.
pub(super) enum FrameAction {
    /// Reply computed inline: `(frame, shutdown, traced, opened)`.
    Reply(Json, bool, bool, Option<TraceId>),
    /// A `hello` negotiation. The transport loop owns the framing
    /// state, so IT builds the ack and flips (or declines).
    Hello { id: String, wire: String },
    /// Run on the slow lane ([`run_slow`]), off the reactor thread.
    Slow(SlowJob),
}

/// A unit of slow-lane work: a `get_kernel` memory miss (refresh +
/// claim + enqueue I/O) or a whole `batch` frame.
pub(super) enum SlowJob {
    Miss(MissJob),
    Batch { id: String, items: Vec<Result<BatchItem, Reject>>, parse_s: f64 },
}

/// A memory miss, probed but unanswered: everything
/// [`serve_memory_miss`] needs, detached from the reactor thread.
pub(super) struct MissJob {
    id: String,
    workload: Workload,
    cfg: SearchConfig,
    key: String,
    trace: ReqTrace,
}

/// A slow-lane reply body. Kernel replies keep their typed form so the
/// binary wire can encode them parse-free (kind 2); everything else is
/// already a JSON frame.
pub(super) enum SlowReplyBody {
    Kernel(KernelReply),
    Frame(Json),
}

impl SlowReplyBody {
    pub(super) fn into_json(self) -> Json {
        match self {
            SlowReplyBody::Kernel(reply) => reply.to_json(),
            SlowReplyBody::Frame(frame) => frame,
        }
    }
}

/// Parse one line-JSON frame and answer as much of it as the fast
/// path can: everything except memory misses and batches, which come
/// back as [`FrameAction::Slow`] for the slow lane.
pub(super) fn dispatch_fast(ctx: &Ctx, line: &str) -> FrameAction {
    let t0 = Instant::now();
    let parsed = Request::parse_line(line);
    let parse_s = t0.elapsed().as_secs_f64();
    match parsed {
        Err(rej) => FrameAction::Reply(rej.to_json(), false, false, None),
        Ok(Request::Shutdown { id }) => {
            FrameAction::Reply(Response::ShutdownAck { id }.to_json(), true, false, None)
        }
        Ok(Request::Stats { id }) => {
            FrameAction::Reply(stats_reply(ctx, id).to_json(), false, false, None)
        }
        Ok(Request::Metrics { id }) => {
            FrameAction::Reply(metrics_reply(ctx, id).to_json(), false, false, None)
        }
        Ok(Request::Health { id }) => {
            FrameAction::Reply(health_reply(ctx, id).to_json(), false, false, None)
        }
        Ok(Request::Traces { id, slowest }) => {
            FrameAction::Reply(traces_reply(ctx, id, slowest).to_json(), false, false, None)
        }
        Ok(Request::Hello { id, wire }) => {
            ctx.note_hello();
            FrameAction::Hello { id, wire }
        }
        Ok(Request::GetKernel { id, workload, gpu, mode, trace: wire }) => {
            let wire = wire.as_deref().and_then(TraceId::from_hex);
            match serve_get_kernel(ctx, id, workload, gpu, mode, t0, parse_s, wire) {
                Ok((reply, opened)) => FrameAction::Reply(reply.to_json(), false, true, opened),
                Err(job) => FrameAction::Slow(SlowJob::Miss(job)),
            }
        }
        Ok(Request::Batch { id, items }) => {
            FrameAction::Slow(SlowJob::Batch { id, items, parse_s })
        }
    }
}

/// Finish one slow-lane job (blocking I/O allowed here).
pub(super) fn run_slow(ctx: &Ctx, job: SlowJob) -> (SlowReplyBody, Option<TraceId>) {
    match job {
        SlowJob::Miss(job) => {
            let (reply, opened) = finish_miss(ctx, job);
            (SlowReplyBody::Kernel(reply), opened)
        }
        SlowJob::Batch { id, items, parse_s } => {
            (SlowReplyBody::Frame(serve_batch(ctx, id, items, parse_s).to_json()), None)
        }
    }
}

/// The miss continuation: targeted shard refresh, fleet claim, search
/// enqueue — every blocking step the probe deferred.
pub(super) fn finish_miss(ctx: &Ctx, job: MissJob) -> (KernelReply, Option<TraceId>) {
    let MissJob { id, workload, cfg, key, mut trace } = job;
    let reply = serve_memory_miss(ctx, id, workload, cfg, key, &mut trace);
    let opened = trace.opened;
    (reply, opened)
}

/// Answer a `trace` frame: the ring's retained traces, slowest first
/// (`slowest == 0` returns every completed trace), cloned out under
/// the trace lock only.
fn traces_reply(ctx: &Ctx, id: String, slowest: usize) -> TraceReply {
    let traces = ctx.traces.lock().expect("traces lock");
    TraceReply { id, traces: traces.slowest(slowest).into_iter().cloned().collect() }
}

fn stats_reply(ctx: &Ctx, id: String) -> StatsReply {
    // Store counters read through the per-shard locks (no daemon-wide
    // lock). Counts reflect what this daemon has ingested: the miss
    // path's per-key refresh pulls foreign write-backs in as they are
    // requested. No full-store refresh here — stats is polled in tight
    // loops (wait_for_drain) and must not stall on an all-shard scan.
    let n_shards = ctx.store.n_shards();
    let shard_records = ctx.store.shard_sizes();
    // One shard-lock walk, not two: the total is the histogram's sum.
    let n_records = shard_records.iter().sum();
    // The REAL worker-queue depth (queued or running jobs). The old
    // frames reported the pending-key count here, conflating the pool
    // with backlogged and in-flight keys.
    let queue_depth = ctx.pool_depth.load(Ordering::SeqCst);
    let state = ctx.state.lock().expect("state lock");
    StatsReply {
        id,
        n_requests: state.metrics.n_requests,
        n_hits: state.metrics.n_hits,
        n_misses: state.metrics.n_misses,
        n_enqueued: state.metrics.n_enqueued,
        n_searches_done: state.metrics.n_searches_done,
        n_evicted_records: state.metrics.n_evicted_records,
        queue_depth,
        n_records,
        n_shards,
        hit_rate: state.metrics.hit_rate(),
        p50_reply_s: state.metrics.p50_reply_s(),
        p99_reply_s: state.metrics.p99_reply_s(),
        measurements_paid: state.metrics.measurements_paid,
        n_shed: state.metrics.n_shed,
        n_fleet_coalesced: state.metrics.n_fleet_coalesced,
        n_static_tier: state.metrics.n_static_tier,
        backlog_len: state.backlog.len(),
        pending_keys: state.pending.len(),
        n_writebacks_fenced: state.metrics.n_writebacks_fenced,
        n_writebacks_dropped: state.metrics.n_writebacks_dropped,
        n_batch_frames: state.metrics.n_batch_frames,
        n_batch_requests: state.metrics.n_batch_requests,
        n_notify_refresh: state.metrics.n_notify_refresh,
        n_poll_refresh: state.metrics.n_poll_refresh,
        uptime_s: ctx.started.elapsed().as_secs_f64(),
        build_info: build_info(),
        shard_records,
        heat_histogram: state.heat.histogram().to_vec(),
    }
}

/// Answer a `metrics` frame: the full telemetry view — every counter
/// plus the reply-time and per-stage histograms — cloned out under one
/// state-lock acquisition. Clients merge these across a fleet.
fn metrics_reply(ctx: &Ctx, id: String) -> MetricsReply {
    let state = ctx.state.lock().expect("state lock");
    let m = &state.metrics;
    MetricsReply {
        id,
        counters: m.counter_pairs().iter().map(|&(k, v)| (k.to_string(), v)).collect(),
        reply_sim_s: m.reply_sim().clone(),
        reply_wall_s: m.reply_wall().clone(),
        stages: Stage::ALL.iter().map(|&s| (s.name().to_string(), m.stage(s).clone())).collect(),
        model: m.model_pairs().into_iter().map(|(k, h)| (k, h.clone())).collect(),
        energy: m.ledger.clone(),
    }
}

/// The effective search config of one request: daemon template +
/// per-request overrides. Workers never write back themselves — the
/// daemon owns the store.
fn request_cfg(ctx: &Ctx, gpu: Option<GpuArch>, mode: Option<SearchMode>) -> SearchConfig {
    let mut cfg = ctx.search.clone();
    if let Some(g) = gpu {
        cfg.gpu = g;
    }
    if let Some(m) = mode {
        cfg.mode = m;
    }
    cfg.store.dir = None;
    cfg.store.write_back = false;
    cfg
}

/// The probe half of `get_kernel`, shared by the line and binary
/// wires: config + key resolution, heat credit, and the per-shard
/// memory probe. A hit is answered right here (the entire fast path —
/// microseconds, no blocking I/O beyond the shard read, safe on a
/// reactor thread); a memory miss comes back as the [`MissJob`] that
/// [`finish_miss`] completes, inline on the blocking path or on the
/// slow lane on the evented one.
#[allow(clippy::too_many_arguments)]
pub(super) fn serve_get_kernel(
    ctx: &Ctx,
    id: String,
    workload: Workload,
    gpu: Option<GpuArch>,
    mode: Option<SearchMode>,
    t0: Instant,
    parse_s: f64,
    wire_trace: Option<TraceId>,
) -> Result<(KernelReply, Option<TraceId>), MissJob> {
    let mut trace = ReqTrace::begin(t0);
    trace.wire = wire_trace;
    trace.stages.add(Stage::Parse, parse_s);
    let cfg = request_cfg(ctx, gpu, mode);
    let key = serve_key(&workload.id(), cfg.gpu.name(), cfg.mode.name(), &config_fingerprint(&cfg));

    // Heat credit under the small lock; released before any store I/O.
    ctx.state.lock().expect("state lock").heat.touch(&key);

    // Exact hit straight from memory: NO per-request refresh I/O — the
    // notify/poll refresh loop streams foreign write-backs in off the
    // request path. A request racing ahead of its notify falls through
    // to the memory-miss job below, whose targeted refresh still
    // finds the landed record.
    let t = Instant::now();
    let found = ctx.store.get(workload, &cfg);
    trace.stages.add(Stage::ShardRead, t.elapsed().as_secs_f64());
    if let Some(rec) = found {
        let reply = serve_hit(ctx, id, &key, &rec, &trace);
        return Ok((reply, trace.opened));
    }
    Err(MissJob { id, workload, cfg, key, trace })
}

/// Serve an exact hit: the recorded, measured kernel, zero cost.
/// Telemetry here is deliberately free — `Instant` reads are vDSO
/// calls and the histogram records fold under the state-lock
/// acquisition the reply bookkeeping takes anyway, so tracing adds no
/// allocation and no syscall to the hottest path in the daemon.
fn serve_hit(
    ctx: &Ctx,
    id: String,
    key: &str,
    rec: &TuningRecord,
    trace: &ReqTrace,
) -> KernelReply {
    if let Err(e) = ctx.store.mark_served(key) {
        eprintln!("serve: LRU touch failed for {key}: {e:#}");
    }
    let t = reply_time_s(true, ctx.store.shard_len_for(key));
    let wall_s = trace.start.elapsed().as_secs_f64();
    // Ledger indices resolved BEFORE the lock (`&str` compares, no
    // allocation); records with no persisted baseline credit 0 J into
    // the `unattributed` family — counted, never guessed.
    let gpu_idx = ledger_gpu_index(&rec.gpu);
    let (family, saved_j) = match rec.baseline_energy_j {
        Some(base) => (ledger_family_index(rec.workload.family()), base - rec.best.energy_j),
        None => (UNATTRIBUTED, 0.0),
    };
    let queue_depth = {
        let mut state = ctx.state.lock().expect("state lock");
        state.metrics.record_reply(true, t, wall_s, &trace.stages);
        if let Some(gpu) = gpu_idx {
            state.metrics.ledger.record_saved(gpu, family, saved_j);
        }
        state.pending.len()
    };
    emit_served(ctx, &id, key, "hit", ServeSource::Store, ServeTier::Exact, t);
    KernelReply {
        id,
        hit: true,
        source: ServeSource::Store,
        tier: ServeTier::Exact,
        schedule: rec.best.schedule,
        latency_s: rec.best.latency_s,
        energy_j: rec.best.energy_j,
        avg_power_w: rec.best.avg_power_w,
        enqueued: false,
        queue_depth,
        reply_time_s: t,
    }
}

/// The key is not in memory: one targeted fleet refresh of its shard —
/// did another daemon land this key since the notify loop last ran? —
/// then the real miss machinery. Takes only the key's shard lock, so
/// hits on other shards keep flowing while this waits on disk.
fn serve_memory_miss(
    ctx: &Ctx,
    id: String,
    workload: Workload,
    cfg: SearchConfig,
    key: String,
    trace: &mut ReqTrace,
) -> KernelReply {
    let t = Instant::now();
    let refreshed = ctx.store.refresh_key(&key);
    trace.stages.add(Stage::ClaimIo, t.elapsed().as_secs_f64());
    match refreshed {
        Ok(0) => {}
        Ok(_) => {
            refresh_snapshot(ctx);
            let t = Instant::now();
            let found = ctx.store.get(workload, &cfg);
            trace.stages.add(Stage::ShardRead, t.elapsed().as_secs_f64());
            if let Some(rec) = found {
                return serve_hit(ctx, id, &key, &rec, trace);
            }
        }
        Err(e) => eprintln!("serve: shard refresh failed for {key}: {e:#}"),
    }
    serve_miss(ctx, id, workload, cfg, key, trace)
}

/// A true miss: best warm guess now (the store's incremental neighbor
/// index — candidate buckets, not a full scan), real search in the
/// background. With no neighbor in range the reply falls to the
/// search-free static tier: the space's best statically-ranked
/// schedule with closed-form estimates — zero measurements paid.
fn serve_miss(
    ctx: &Ctx,
    id: String,
    workload: Workload,
    cfg: SearchConfig,
    key: String,
    trace: &mut ReqTrace,
) -> KernelReply {
    let shard_len = ctx.store.shard_len_for(&key);
    let t_lookup = Instant::now();
    let spec = cfg.gpu.spec();
    let space = ScheduleSpace::new(workload, &spec);
    let guess = {
        let neighbors = ctx.store.neighbors(workload, cfg.gpu.name(), 1);
        neighbors
            .first()
            .filter(|(_, dist)| *dist <= MAX_TRANSFER_DISTANCE)
            .and_then(|(rec, _)| {
                relegalize(&rec.best.schedule, &space).map(|s| {
                    let scale = workload.gemm_view().macs() as f64
                        / (rec.workload.gemm_view().macs() as f64).max(1.0);
                    (s, rec.best.latency_s * scale, rec.best.energy_j * scale, rec.best.avg_power_w)
                })
            })
    };
    trace.stages.add(Stage::SnapshotLookup, t_lookup.elapsed().as_secs_f64());
    let (served, source, tier) = match guess {
        Some((s, lat, en, pw)) => (
            StoredKernel { schedule: s, latency_s: lat, energy_j: en, avg_power_w: pw },
            ServeSource::WarmGuess,
            ServeTier::Warm,
        ),
        // No neighbor close enough to estimate from: static tier — the
        // best of a capped, statically-ranked enumeration, with the
        // analyzer's closed-form estimates instead of 0.0 "unknown".
        None => {
            let (s, prof) = crate::analysis::best_static(workload, &spec);
            (StoredKernel::from_static(s, &prof), ServeSource::Fallback, ServeTier::Static)
        }
    };

    // Who searches this key? Local duplicates coalesce on `pending`;
    // fleet duplicates coalesce on the in-store claim. The claim is
    // several file ops plus a settle pause, so it runs OUTSIDE the
    // state lock — a burst of cold misses must not stall concurrent
    // reply bookkeeping.
    let mut state = ctx.state.lock().expect("state lock");
    let mut reserve = false;
    if !state.pending.contains_key(&key) {
        if ctx.search.fleet.coordinate {
            drop(state);
            let t_claim = Instant::now();
            let attempt = ctx.inflight.claim(&key);
            trace.stages.add(Stage::ClaimIo, t_claim.elapsed().as_secs_f64());
            state = ctx.state.lock().expect("state lock");
            match attempt {
                Ok(Some(lease)) => {
                    // Concurrent requests for this key may both have
                    // claimed while unlocked (same holder — each
                    // reacquire bumps the epoch). Only the NEWEST
                    // epoch matches the claim file, so that is the
                    // lease the write-back fence must check — and
                    // map-insert order follows lock reacquisition
                    // order, not claim order, so compare explicitly.
                    let raced = state.pending.contains_key(&key);
                    let newest = match state.claims.get(&key) {
                        Some(held) => lease.epoch() > held.epoch(),
                        None => true,
                    };
                    if newest {
                        state.claims.insert(key.clone(), lease);
                    }
                    reserve = !raced;
                }
                Ok(None) => {
                    if !state.pending.contains_key(&key) {
                        // Another daemon is already searching this key:
                        // serve the warm guess, its write-back lands.
                        state.metrics.n_fleet_coalesced += 1;
                    }
                }
                Err(e) => {
                    if !state.pending.contains_key(&key) {
                        eprintln!(
                            "serve: in-flight claim failed for {key}: {e:#} (running unfenced)"
                        );
                        reserve = true;
                    }
                }
            }
        } else {
            // Uncoordinated (single-owner) mode: nothing to claim.
            reserve = true;
        }
    }
    let mut opened: Option<TraceId> = None;
    if reserve {
        // The reserving miss mints (or adopts the client's) trace id;
        // duplicates coalescing on `pending` ride the same trace, so a
        // key searched once fleet-wide yields exactly one trace.
        let tid = trace.wire.unwrap_or_else(TraceId::mint);
        opened = Some(tid);
        state.pending.insert(key.clone(), PendingMiss { req: id.clone(), trace: tid });
        state.metrics.n_enqueued += 1;
    }
    let snapshot = state.snapshot.clone();
    let queue_depth = state.pending.len();
    let t = reply_time_s(false, shard_len);
    drop(state);

    // The reply reports what actually happened: `enqueued` means the
    // search was admitted (worker queue or heat-ordered backlog). A
    // saturated daemon sheds the coldest key instead — a shed key's
    // claim is released so any daemon's next request for it retries.
    let mut enqueued = false;
    let mut shed_event: Option<(String, &'static str, Option<PendingMiss>)> = None;
    let mut via = "queue";
    let t_enqueue = Instant::now();
    if reserve {
        let job = SearchJob { name: key.clone(), workload, cfg };
        let direct = {
            let mut pool = ctx.pool.lock().expect("pool lock");
            match pool.as_mut() {
                Some(p) => p.try_submit_with_snapshot(job.clone(), Some(snapshot.clone())),
                None => false, // shutting down
            }
        };
        if direct {
            enqueued = true;
        } else {
            let mut state = ctx.state.lock().expect("state lock");
            let ServeState { backlog, heat, pending, claims, metrics, .. } = &mut *state;
            match backlog.offer(key.clone(), (job, snapshot), heat) {
                Offer::Queued => {
                    enqueued = true;
                    via = "backlog";
                }
                Offer::Displaced { key: shed_key, .. } => {
                    enqueued = true;
                    via = "backlog";
                    let p = pending.remove(&shed_key);
                    metrics.n_enqueued -= 1;
                    metrics.n_shed += 1;
                    if let Some(lease) = claims.remove(&shed_key) {
                        let _ = lease.release();
                    }
                    shed_event = Some((shed_key, "displaced_by_hotter_key", p));
                }
                Offer::Rejected { key: cold_key, .. } => {
                    let p = pending.remove(&cold_key);
                    metrics.n_enqueued -= 1;
                    metrics.n_shed += 1;
                    if let Some(lease) = claims.remove(&cold_key) {
                        let _ = lease.release();
                    }
                    shed_event = Some((cold_key, "colder_than_backlog", p));
                }
            }
        }
        trace.stages.add(Stage::Enqueue, t_enqueue.elapsed().as_secs_f64());
    }
    // Reply bookkeeping runs AFTER the enqueue so the trace carries
    // every stage this miss touched; the lock reacquisition is cold-
    // path only (the hit path records under its one acquisition).
    let wall_s = trace.start.elapsed().as_secs_f64();
    {
        let mut state = ctx.state.lock().expect("state lock");
        state.metrics.record_reply(false, t, wall_s, &trace.stages);
        if tier == ServeTier::Static {
            state.metrics.n_static_tier += 1;
        }
    }
    // The reserving miss opens the distributed trace — the hot-path
    // stages become its first spans (cumulative offsets, hot-path
    // order). Search rounds and the write-back attach at the terminal
    // landing; reply-write after the bytes actually leave the socket.
    if let Some(tid) = opened {
        trace.opened = Some(tid);
        let mut traces = ctx.traces.lock().expect("traces lock");
        traces.open(tid, &key, &id, unix_now_s() - wall_s);
        let mut off = 0.0;
        for stage in Stage::ALL {
            if stage == Stage::ReplyWrite {
                continue; // measured by the connection loop post-flush
            }
            if let Some(secs) = trace.stages.get(stage) {
                traces.span(tid, Span::new(stage.name(), off, secs));
                off += secs;
            }
        }
    }
    // A shed key's trace terminates here (possibly the one just
    // opened, when this very miss was the coldest offer).
    if let Some((_, reason, p)) = &shed_event {
        close_shed_trace(ctx, p.as_ref(), reason);
    }
    if let Some(log) = &ctx.log {
        if enqueued {
            log.emit_traced(
                "job_enqueued",
                &id,
                vec![
                    ("key", Json::str(key.clone())),
                    ("queue_depth", Json::num(queue_depth as f64)),
                    ("via", Json::str(via)),
                ],
            );
        }
        if let Some((shed_key, reason, _)) = shed_event {
            log.emit(
                "job_shed",
                vec![("key", Json::str(shed_key)), ("reason", Json::str(reason))],
            );
        }
    }
    emit_served(ctx, &id, &key, "miss", source, tier, t);
    KernelReply {
        id,
        hit: false,
        source,
        tier,
        schedule: served.schedule,
        latency_s: served.latency_s,
        energy_j: served.energy_j,
        avg_power_w: served.avg_power_w,
        enqueued,
        queue_depth,
        reply_time_s: t,
    }
}

/// Answer one `batch` frame: N `get_kernel` requests in, N
/// positionally-matched replies out, all in one socket write.
///
/// Two passes keep the cheap positions cheap. Pass 1 answers
/// everything that needs no claim or refresh I/O — parse rejects
/// become positional error frames and in-memory exact hits are served
/// under per-shard read locks only — so a hit at position *k* never
/// waits behind a sibling miss's in-store claim file ops. Pass 2 runs
/// the misses through the normal machinery (targeted shard refresh,
/// fleet claim, warm guess, admission); duplicates WITHIN the batch
/// coalesce exactly like duplicates across frames (the first reserves
/// `pending`, the rest ride along).
fn serve_batch(
    ctx: &Ctx,
    id: String,
    items: Vec<Result<BatchItem, Reject>>,
    parse_s: f64,
) -> Response {
    let n = items.len();
    let mut replies: Vec<Option<Response>> = vec![None; n];
    let mut misses: Vec<(usize, BatchItem, SearchConfig, String, ReqTrace)> = Vec::new();
    for (i, item) in items.into_iter().enumerate() {
        match item {
            Err(rej) => {
                replies[i] = Some(Response::Error {
                    id: rej.id,
                    code: rej.code.to_string(),
                    message: rej.message,
                });
            }
            Ok(item) => {
                let cfg = request_cfg(ctx, item.gpu, item.mode);
                let key = serve_key(
                    &item.workload.id(),
                    cfg.gpu.name(),
                    cfg.mode.name(),
                    &config_fingerprint(&cfg),
                );
                ctx.state.lock().expect("state lock").heat.touch(&key);
                // Per-item wall clock starts when the batch reaches the
                // item; the frame-level parse is recorded once below.
                let mut trace = ReqTrace::begin(Instant::now());
                let t = Instant::now();
                let found = ctx.store.get(item.workload, &cfg);
                trace.stages.add(Stage::ShardRead, t.elapsed().as_secs_f64());
                if let Some(rec) = found {
                    let hit = serve_hit(ctx, item.id.clone(), &key, &rec, &trace);
                    replies[i] = Some(Response::Kernel(hit));
                } else {
                    misses.push((i, item, cfg, key, trace));
                }
            }
        }
    }
    let mut refreshed_keys: HashSet<String> = HashSet::new();
    for (i, item, cfg, key, mut trace) in misses {
        let reply = if refreshed_keys.insert(key.clone()) {
            serve_memory_miss(ctx, item.id, item.workload, cfg, key, &mut trace)
        } else if let Some(rec) = ctx.store.get(item.workload, &cfg) {
            // An earlier duplicate's targeted refresh pulled the key in
            // (another daemon had landed it): plain hit, no re-refresh.
            serve_hit(ctx, item.id, &key, &rec, &trace)
        } else {
            // An earlier position already paid this key's targeted
            // refresh within this frame — skip straight to the miss
            // machinery, where `pending` coalesces the search.
            serve_miss(ctx, item.id, item.workload, cfg, key, &mut trace)
        };
        replies[i] = Some(Response::Kernel(reply));
    }
    {
        let mut state = ctx.state.lock().expect("state lock");
        state.metrics.n_batch_frames += 1;
        state.metrics.n_batch_requests += n;
        // The frame parse covered all N positions in one go — charge it
        // once per frame, same as the wire charged one syscall.
        state.metrics.record_stage(Stage::Parse, parse_s);
    }
    // Defensive: both passes above answer every position. Should a gap
    // ever appear, the client gets a positional internal-error frame —
    // the daemon's request path never panics (see
    // scripts/check_invariants.py).
    let replies = replies
        .into_iter()
        .map(|r| {
            r.unwrap_or_else(|| Response::Error {
                id: None,
                code: error_code::INTERNAL.to_string(),
                message: "batch position left unanswered".to_string(),
            })
        })
        .collect();
    Response::Batch { id, replies }
}

fn emit_served(
    ctx: &Ctx,
    req: &str,
    key: &str,
    result: &str,
    source: ServeSource,
    tier: ServeTier,
    reply_time: f64,
) {
    if let Some(log) = &ctx.log {
        log.emit_traced(
            "job_served",
            req,
            vec![
                ("key", Json::str(key)),
                ("result", Json::str(result)),
                ("source", Json::str(source.name())),
                ("tier", Json::str(tier.name())),
                ("reply_time_s", Json::num(reply_time)),
                ("protocol_v", Json::num(PROTOCOL_VERSION as f64)),
            ],
        );
    }
}
