//! The artifact registry: indexes `artifacts/manifest.json` and maps a
//! searched schedule onto the nearest AOT-compiled variant.

use super::artifact::{ArtifactMeta, LoadedKernel};
use crate::schedule::Schedule;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Index over the artifacts directory.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    /// workload_id -> variants
    by_workload: HashMap<String, Vec<ArtifactMeta>>,
}

impl ArtifactRegistry {
    /// Open a registry rooted at `dir` (expects `manifest.json`).
    pub fn open(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?} (run `make artifacts`)"))?;
        let json = crate::util::Json::parse(&text)
            .map_err(|e| anyhow!("parse manifest.json: {e}"))?;
        let entries = json.as_arr().ok_or_else(|| anyhow!("manifest must be an array"))?;
        let mut by_workload: HashMap<String, Vec<ArtifactMeta>> = HashMap::new();
        for entry in entries {
            let meta = ArtifactMeta::from_json(dir, entry)?;
            anyhow::ensure!(meta.file.exists(), "missing artifact file {:?}", meta.file);
            by_workload.entry(meta.workload_id.clone()).or_default().push(meta);
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), by_workload })
    }

    /// The default registry location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        // Honour ECOKERNEL_ARTIFACTS for tests and deployments.
        if let Ok(dir) = std::env::var("ECOKERNEL_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        PathBuf::from("artifacts")
    }

    pub fn workload_ids(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.by_workload.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn variants(&self, workload_id: &str) -> &[ArtifactMeta] {
        self.by_workload.get(workload_id).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn n_artifacts(&self) -> usize {
        self.by_workload.values().map(|v| v.len()).sum()
    }

    /// Exact lookup by variant id.
    pub fn get(&self, workload_id: &str, variant_id: &str) -> Option<&ArtifactMeta> {
        self.variants(workload_id).iter().find(|m| m.variant_id == variant_id)
    }

    /// The palette variant nearest (in log-tile space) to a searched
    /// schedule's block geometry. This is how a search winner becomes a
    /// runnable kernel.
    pub fn nearest(&self, workload_id: &str, sched: &Schedule) -> Option<&ArtifactMeta> {
        let (bm, bn, bk) =
            (sched.block_m() as f64, sched.block_n() as f64, sched.tile_k as f64);
        self.variants(workload_id).iter().min_by(|a, b| {
            let d = |m: &ArtifactMeta| {
                let lm = (m.bm as f64 / bm).ln().abs();
                let ln_ = (m.bn as f64 / bn).ln().abs();
                let lk = (m.bk as f64 / bk).ln().abs();
                lm + ln_ + lk
            };
            d(a).partial_cmp(&d(b)).expect("finite distance")
        })
    }

    /// Load + compile one variant.
    pub fn load(&self, meta: &ArtifactMeta) -> Result<LoadedKernel> {
        LoadedKernel::load(meta.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Option<ArtifactRegistry> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        ArtifactRegistry::open(&dir).ok()
    }

    #[test]
    fn registry_indexes_manifest() {
        let Some(reg) = registry() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(reg.n_artifacts() >= 40, "{}", reg.n_artifacts());
        assert!(reg.workload_ids().contains(&"mm_b1_m512_n512_k512"));
    }

    #[test]
    fn nearest_picks_matching_geometry() {
        let Some(reg) = registry() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let sched = Schedule {
            threads_m: 8,
            threads_n: 8,
            reg_m: 8,
            reg_n: 8,
            tile_k: 16,
            unroll_k: 4,
            vector_width: 4,
            split_k: 1,
            use_shared: true,
        };
        // block = 64x64, bk=16 — exact palette member.
        let m = reg.nearest("mm_b1_m512_n512_k512", &sched).expect("variant");
        assert_eq!((m.bm, m.bn, m.bk), (64, 64, 16));

        // An off-palette geometry snaps to the closest member.
        let odd = Schedule { threads_m: 4, reg_m: 2, ..sched }; // block_m = 8
        let m2 = reg.nearest("mm_b1_m512_n512_k512", &odd).expect("variant");
        assert_eq!(m2.bm, 16, "snaps up to the smallest palette bm");
    }
}
