//! PJRT client wrapper: one lazily-created CPU client **per thread**.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so the
//! process-wide sharing is thread-local: each thread that touches the
//! runtime gets its own client on first use. Artifact execution in the
//! examples and experiments is single-threaded, so in practice one
//! client is created per process.

use anyhow::Result;
use std::cell::OnceCell;

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// Run `f` with this thread's CPU PJRT client (created on first use).
pub fn with_client<T>(f: impl FnOnce(&xla::PjRtClient) -> Result<T>) -> Result<T> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
            let _ = cell.set(client);
        }
        f(cell.get().expect("client initialized"))
    })
}

/// Report the PJRT platform (e.g. "cpu") and device count.
pub fn platform_info() -> Result<(String, usize)> {
    with_client(|c| Ok((c.platform_name(), c.device_count())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_initializes_and_reports_cpu() {
        let (platform, devices) = platform_info().expect("client");
        assert_eq!(platform, "cpu");
        assert!(devices >= 1);
    }
}
