//! Loading and executing one AOT artifact (HLO text → PJRT executable).
//!
//! Interchange is HLO *text*: `HloModuleProto::from_text_file` reparses
//! and reassigns instruction ids, sidestepping the 64-bit-id protos
//! that jax >= 0.5 emits and xla_extension 0.5.1 rejects.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Metadata for one artifact (one manifest.json entry).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub workload_id: String,
    pub op: String,
    pub variant_id: String,
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
    pub file: PathBuf,
    /// Expected input shapes, outermost-first.
    pub arg_shapes: Vec<Vec<usize>>,
}

impl ArtifactMeta {
    /// Parse one manifest entry.
    pub fn from_json(dir: &Path, v: &crate::util::Json) -> Result<ArtifactMeta> {
        let get_str = |k: &str| -> Result<String> {
            Ok(v.get(k)
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("manifest entry missing '{k}'"))?
                .to_string())
        };
        let get_usize = |k: &str| -> Result<usize> {
            Ok(v.get(k)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| anyhow!("manifest entry missing '{k}'"))? as usize)
        };
        let arg_shapes = v
            .get("arg_shapes")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("manifest entry missing 'arg_shapes'"))?
            .iter()
            .map(|shape| {
                shape
                    .as_arr()
                    .map(|dims| {
                        dims.iter().filter_map(|d| d.as_f64()).map(|d| d as usize).collect()
                    })
                    .ok_or_else(|| anyhow!("bad arg shape"))
            })
            .collect::<Result<Vec<Vec<usize>>>>()?;
        Ok(ArtifactMeta {
            workload_id: get_str("workload_id")?,
            op: get_str("op")?,
            variant_id: get_str("variant_id")?,
            bm: get_usize("bm")?,
            bn: get_usize("bn")?,
            bk: get_usize("bk")?,
            file: dir.join(get_str("file")?),
            arg_shapes,
        })
    }

    pub fn name(&self) -> String {
        format!("{}__{}", self.workload_id, self.variant_id)
    }
}

/// A compiled, executable kernel.
pub struct LoadedKernel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    /// Wall-clock time spent compiling (for the perf log).
    pub compile_time: std::time::Duration,
}

impl LoadedKernel {
    /// Load the HLO text and compile it on the shared PJRT CPU client.
    pub fn load(meta: ArtifactMeta) -> Result<LoadedKernel> {
        let t0 = Instant::now();
        let path = meta
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = super::client::with_client(|c| {
            c.compile(&comp).map_err(|e| anyhow!("PJRT compile: {e}"))
        })?;
        Ok(LoadedKernel { meta, exe, compile_time: t0.elapsed() })
    }

    /// Execute with f32 inputs; returns the flattened f32 output of the
    /// (single-element) result tuple.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            inputs.len() == self.meta.arg_shapes.len(),
            "expected {} inputs, got {}",
            self.meta.arg_shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().enumerate() {
            let want: usize = self.meta.arg_shapes[i].iter().product();
            anyhow::ensure!(
                data.len() == want,
                "input {i}: expected {want} f32s for shape {:?}, got {}",
                self.meta.arg_shapes[i],
                data.len()
            );
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {i}: {e}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
    }

    /// Time one execution (seconds) with the given inputs.
    pub fn time_once(&self, inputs: &[(&[f32], &[usize])]) -> Result<f64> {
        let t0 = Instant::now();
        let _ = self.run_f32(inputs)?;
        Ok(t0.elapsed().as_secs_f64())
    }
}
