//! Runtime: load AOT-compiled HLO artifacts and execute them through
//! the PJRT C API (`xla` crate). Python never runs here — the artifacts
//! were lowered once at build time by `python/compile/aot.py`.

pub mod artifact;
pub mod client;
pub mod registry;

pub use artifact::{ArtifactMeta, LoadedKernel};
pub use registry::ArtifactRegistry;
