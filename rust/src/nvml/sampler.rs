//! The power sampler: a 30–50 Hz noisy sensor over the simulator's true
//! instantaneous power, mimicking `nvmlDeviceGetPowerUsage`.

use crate::config::NvmlConfig;
use crate::util::Rng;

/// One standard-normal draw (delegates to the in-tree Box–Muller).
pub fn normal_draw(rng: &mut Rng) -> f64 {
    rng.normal()
}

/// Samples noisy power readings at a fixed rate.
#[derive(Debug, Clone)]
pub struct PowerSampler {
    cfg: NvmlConfig,
}

impl PowerSampler {
    pub fn new(cfg: NvmlConfig) -> Self {
        PowerSampler { cfg }
    }

    pub fn sampling_period_s(&self) -> f64 {
        1.0 / self.cfg.sampling_hz
    }

    /// Number of kernel repetitions needed so that `min_samples` power
    /// samples land inside the execution window.
    pub fn reps_for(&self, kernel_latency_s: f64) -> usize {
        let window_s = self.cfg.min_samples as f64 * self.sampling_period_s();
        let reps = (window_s / kernel_latency_s.max(1e-9)).ceil() as usize;
        reps.clamp(1, self.cfg.max_reps)
    }

    /// Draw `n` noisy samples around `true_power_w`; returns (samples,
    /// mean).
    pub fn sample_n(&self, true_power_w: f64, n: usize, rng: &mut Rng) -> (Vec<f64>, f64) {
        let sigma = (true_power_w * self.cfg.power_noise_rel).max(1e-9);
        let samples: Vec<f64> =
            (0..n).map(|_| (true_power_w + sigma * normal_draw(rng)).max(0.0)).collect();
        let mean = samples.iter().sum::<f64>() / n.max(1) as f64;
        (samples, mean)
    }

    /// One noisy latency timing around `true_latency_s`.
    pub fn time_latency(&self, true_latency_s: f64, rng: &mut Rng) -> f64 {
        let sigma = (true_latency_s * self.cfg.latency_noise_rel).max(1e-15);
        (true_latency_s + sigma * normal_draw(rng)).max(true_latency_s * 0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NvmlConfig;
    
    

    #[test]
    fn reps_scale_inversely_with_latency() {
        let s = PowerSampler::new(NvmlConfig::default());
        // A 1 ms kernel needs ~1111 reps for 50 samples at 45 Hz.
        let fast = s.reps_for(1e-3);
        let slow = s.reps_for(10e-3);
        assert!(fast > slow);
        assert!(fast >= 1000, "fast={fast}");
        // Paper §5.1: thousands of iterations for ms-scale kernels.
        assert!(s.reps_for(0.5e-3) >= 2000);
    }

    #[test]
    fn reps_capped() {
        let cfg = NvmlConfig { max_reps: 500, ..NvmlConfig::default() };
        let s = PowerSampler::new(cfg);
        assert_eq!(s.reps_for(1e-7), 500);
    }

    #[test]
    fn sample_mean_near_truth() {
        let s = PowerSampler::new(NvmlConfig::default());
        let mut rng = Rng::seed_from_u64(1);
        let (_samples, mean) = s.sample_n(200.0, 500, &mut rng);
        assert!((mean - 200.0).abs() < 2.0, "mean={mean}");
    }

    #[test]
    fn latency_timing_is_noisy_but_close() {
        let s = PowerSampler::new(NvmlConfig::default());
        let mut rng = Rng::seed_from_u64(2);
        let mut sum = 0.0;
        for _ in 0..100 {
            sum += s.time_latency(1e-3, &mut rng);
        }
        let mean = sum / 100.0;
        assert!((mean - 1e-3).abs() / 1e-3 < 0.01);
    }
}
