//! `NvmlMeter`: the full measurement procedure of §4.4.
//!
//! 1. pre-heat the GPU to a consistent temperature (cold-start only);
//! 2. execute the kernel repeatedly until enough power samples exist;
//! 3. average the noisy samples → average power;
//! 4. energy of one run = average power × (noisily timed) latency.
//!
//! Every step advances the device's [`ThermalState`] and charges the
//! [`MeasurementClock`] — measurement is the dominant cost of a search
//! round, which the paper's cost model exists to avoid (Fig. 5).

use super::sampler::PowerSampler;
use super::MeasurementClock;
use crate::config::{GpuSpec, NvmlConfig};
use crate::schedule::Candidate;
use crate::sim::{self, ThermalState};
use crate::util::Rng;

/// One NVML energy measurement result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    /// Measured latency of one kernel run (s).
    pub latency_s: f64,
    /// Measured average power (W).
    pub avg_power_w: f64,
    /// Measured energy of one kernel run (J) = power × latency.
    pub energy_j: f64,
    /// Kernel repetitions executed.
    pub reps: usize,
    /// Power samples collected.
    pub samples: usize,
    /// Die temperature at measurement time (C).
    pub temp_c: f64,
}

/// A simulated NVML-based power/energy meter bound to one GPU device.
#[derive(Debug, Clone)]
pub struct NvmlMeter {
    spec: GpuSpec,
    cfg: NvmlConfig,
    sampler: PowerSampler,
    thermal: ThermalState,
    /// Clock charged by this meter.
    pub clock: MeasurementClock,
}

impl NvmlMeter {
    /// A meter on a *cold* device (first measurement will pre-heat).
    pub fn new(spec: GpuSpec, cfg: NvmlConfig) -> NvmlMeter {
        let thermal = ThermalState::cold(&spec);
        NvmlMeter {
            sampler: PowerSampler::new(cfg.clone()),
            spec,
            cfg,
            thermal,
            clock: MeasurementClock::new(),
        }
    }

    /// A meter on a pre-warmed device (useful in tests).
    pub fn warmed(spec: GpuSpec, cfg: NvmlConfig) -> NvmlMeter {
        let thermal = ThermalState::warmed(&spec);
        NvmlMeter {
            sampler: PowerSampler::new(cfg.clone()),
            spec,
            cfg,
            thermal,
            clock: MeasurementClock::new(),
        }
    }

    pub fn spec(&self) -> &GpuSpec {
        &self.spec
    }

    pub fn temperature_c(&self) -> f64 {
        self.thermal.temp_c
    }

    /// Pre-heat to the measurement steady state (§4.4: "we run a
    /// pre-heating kernel for several seconds to warm up the GPU").
    /// The pre-heating kernel is designed to drive the die to the
    /// steady temperature; charges `warmup_s` to the clock.
    pub fn warm_up(&mut self) {
        if self.thermal.is_steady(1.5) {
            return;
        }
        self.clock.charge_warmup(self.cfg.warmup_s.max(0.5));
        self.thermal = ThermalState::warmed(&self.spec);
    }

    /// Measure energy of `cand` per §4.4. Skipping `warm_up()` first
    /// yields readings biased by the (colder) die temperature.
    pub fn measure(&mut self, cand: &Candidate, rng: &mut Rng) -> Measurement {
        // True behaviour at the *current* temperature.
        let truth = sim::evaluate_at(&cand.gemm(), &cand.schedule, &self.spec, self.thermal.temp_c);

        let reps = self.sampler.reps_for(truth.latency_s);
        let exec_s = reps as f64 * truth.latency_s;
        let samples =
            ((exec_s / self.sampler.sampling_period_s()).floor() as usize).max(1);

        // Running the measurement batch heats the die.
        self.thermal.run_load(exec_s, truth.avg_power_w / self.spec.tdp_w);
        self.clock.charge_kernel_exec(exec_s);
        self.clock.note_energy_measurement();

        let (_all, mean_power) = self.sampler.sample_n(truth.avg_power_w, samples, rng);
        let latency = self.sampler.time_latency(truth.latency_s, rng);

        Measurement {
            latency_s: latency,
            avg_power_w: mean_power,
            energy_j: mean_power * latency,
            reps,
            samples,
            temp_c: self.thermal.temp_c,
        }
    }

    /// Fast latency-only timing (a handful of runs, no power sampling).
    /// This is what `LatencyEvaAndPick` uses for every candidate.
    pub fn time_latency(&mut self, cand: &Candidate, rng: &mut Rng) -> f64 {
        let truth = sim::evaluate_at(&cand.gemm(), &cand.schedule, &self.spec, self.thermal.temp_c);
        // 10 timing runs + launch overheads.
        let eval_s = 10.0 * truth.latency_s + 50e-6;
        self.thermal.run_load(eval_s, truth.avg_power_w / self.spec.tdp_w);
        self.clock.charge_latency_eval(eval_s);
        self.sampler.time_latency(truth.latency_s, rng)
    }

    /// Let the device sit idle (cooling) for `s` seconds.
    pub fn idle(&mut self, s: f64) {
        self.thermal.run_idle(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::workload::suites;
    
    

    fn candidate() -> Candidate {
        let spec = GpuArch::A100.spec();
        let space = crate::schedule::space::ScheduleSpace::new(suites::MM1, &spec);
        Candidate::new(suites::MM1, space.fallback())
    }

    #[test]
    fn measurement_close_to_truth_when_warm() {
        let spec = GpuArch::A100.spec();
        let mut meter = NvmlMeter::warmed(spec.clone(), Default::default());
        let mut rng = Rng::seed_from_u64(1);
        let c = candidate();
        let truth = sim::evaluate_candidate(&c, &spec);
        let m = meter.measure(&c, &mut rng);
        let rel = (m.energy_j - truth.energy_j).abs() / truth.energy_j;
        assert!(rel < 0.08, "relative error {rel}");
        assert!(m.reps > 1, "ms-scale kernels need repetition");
    }

    #[test]
    fn cold_measurement_is_biased_low() {
        // Colder die -> less leakage -> lower measured energy than the
        // warmed steady-state truth. This is the §5.1 pitfall.
        let spec = GpuArch::A100.spec();
        let c = candidate();
        let truth = sim::evaluate_candidate(&c, &spec);
        let mut rng = Rng::seed_from_u64(2);
        let mut cold = NvmlMeter::new(spec.clone(), Default::default());
        let m = cold.measure(&c, &mut rng);
        assert!(
            m.energy_j < truth.energy_j,
            "cold {} !< steady {}",
            m.energy_j,
            truth.energy_j
        );
    }

    #[test]
    fn warm_up_removes_the_bias() {
        let spec = GpuArch::A100.spec();
        let c = candidate();
        let truth = sim::evaluate_candidate(&c, &spec);
        let mut rng = Rng::seed_from_u64(3);
        let mut meter = NvmlMeter::new(spec.clone(), Default::default());
        meter.warm_up();
        assert!(meter.clock.warmup_s > 0.0, "warm-up must cost time");
        let m = meter.measure(&c, &mut rng);
        let rel = (m.energy_j - truth.energy_j).abs() / truth.energy_j;
        assert!(rel < 0.08, "relative error after warm-up {rel}");
    }

    #[test]
    fn measurement_charges_seconds() {
        // §5.1: one measurement takes on the order of seconds.
        let spec = GpuArch::A100.spec();
        let mut meter = NvmlMeter::warmed(spec, Default::default());
        let mut rng = Rng::seed_from_u64(4);
        meter.measure(&candidate(), &mut rng);
        assert!(
            meter.clock.kernel_exec_s > 0.02,
            "exec time {} too cheap",
            meter.clock.kernel_exec_s
        );
        assert_eq!(meter.clock.n_energy_measurements, 1);
    }

    #[test]
    fn latency_timing_is_much_cheaper_than_energy_measurement() {
        let spec = GpuArch::A100.spec();
        let mut rng = Rng::seed_from_u64(5);
        let c = candidate();
        let mut m1 = NvmlMeter::warmed(spec.clone(), Default::default());
        m1.measure(&c, &mut rng);
        let mut m2 = NvmlMeter::warmed(spec, Default::default());
        m2.time_latency(&c, &mut rng);
        assert!(m2.clock.total_s < m1.clock.total_s / 5.0);
    }
}
