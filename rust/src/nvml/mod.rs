//! Simulated NVML power-measurement framework (§4.4, §5.1).
//!
//! Reproduces the paper's measurement *methodology and costs* on top of
//! the simulator:
//!
//! * the power sensor samples at 30–50 Hz — far slower than a kernel
//!   run, so the kernel is repeated until enough samples accumulate;
//! * each sample carries Gaussian noise; latency timing carries noise;
//! * the die temperature drifts with load (leakage ↑ with temp), so a
//!   **warm-up** precedes measurement batches on a cold GPU;
//! * every measurement **charges wall-clock seconds** to a
//!   [`MeasurementClock`] — the currency of the Fig. 5 search-speed
//!   comparison.

pub mod measure;
pub mod sampler;

pub use measure::{Measurement, NvmlMeter};
pub use sampler::PowerSampler;


/// Accumulates the simulated wall-clock cost of measurement and search
/// activities. One clock per (simulated) GPU device.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MeasurementClock {
    /// Total simulated seconds elapsed.
    pub total_s: f64,
    /// Seconds spent in warm-up pre-heating.
    pub warmup_s: f64,
    /// Seconds spent executing kernels under measurement.
    pub kernel_exec_s: f64,
    /// Seconds spent in latency-only timing runs.
    pub latency_eval_s: f64,
    /// Seconds attributed to cost-model prediction (milliseconds each).
    pub model_predict_s: f64,
    /// Seconds attributed to cost-model (re)training.
    pub model_train_s: f64,
    /// Number of full NVML energy measurements taken.
    pub n_energy_measurements: usize,
    /// Number of latency timings taken.
    pub n_latency_timings: usize,
}

impl MeasurementClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn charge_warmup(&mut self, s: f64) {
        self.warmup_s += s;
        self.total_s += s;
    }

    pub fn charge_kernel_exec(&mut self, s: f64) {
        self.kernel_exec_s += s;
        self.total_s += s;
    }

    pub fn charge_latency_eval(&mut self, s: f64) {
        self.latency_eval_s += s;
        self.total_s += s;
        self.n_latency_timings += 1;
    }

    pub fn charge_model_predict(&mut self, s: f64) {
        self.model_predict_s += s;
        self.total_s += s;
    }

    pub fn charge_model_train(&mut self, s: f64) {
        self.model_train_s += s;
        self.total_s += s;
    }

    pub fn note_energy_measurement(&mut self) {
        self.n_energy_measurements += 1;
    }

    /// Merge another clock (e.g. from a worker) into this one.
    pub fn merge(&mut self, other: &MeasurementClock) {
        self.total_s += other.total_s;
        self.warmup_s += other.warmup_s;
        self.kernel_exec_s += other.kernel_exec_s;
        self.latency_eval_s += other.latency_eval_s;
        self.model_predict_s += other.model_predict_s;
        self.model_train_s += other.model_train_s;
        self.n_energy_measurements += other.n_energy_measurements;
        self.n_latency_timings += other.n_latency_timings;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_accumulates_and_merges() {
        let mut a = MeasurementClock::new();
        a.charge_warmup(3.0);
        a.charge_kernel_exec(1.5);
        a.note_energy_measurement();
        let mut b = MeasurementClock::new();
        b.charge_latency_eval(0.25);
        b.charge_model_predict(0.001);
        a.merge(&b);
        assert!((a.total_s - 4.751).abs() < 1e-12);
        assert_eq!(a.n_energy_measurements, 1);
        assert_eq!(a.n_latency_timings, 1);
    }
}
