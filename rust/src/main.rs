//! `ecokernel` — CLI for the energy-efficient kernel generation
//! framework (leader entrypoint).
//!
//! Subcommands:
//!   search      run one kernel search (the paper's core loop)
//!   analyze     static schedule analysis: rank a workload's space by
//!               closed-form energy, dump the profiles as JSON
//!   serve       run the kernel-serving daemon on a Unix socket
//!   query       ask a running daemon for a kernel / stats / metrics / traces / shutdown
//!   bench       serving benchmark: zipf replay against live daemons
//!   experiment  regenerate a paper table/figure (table1..5, fig2..5, all)
//!   cache       inspect / maintain a persistent tuning store
//!   artifacts   inspect / execute the AOT artifact registry
//!   gpus        list simulated GPU spec sheets
//!   config      print the default search config as TOML

use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
use ecokernel::coordinator::{Driver, DriverConfig, EventLog};
use ecokernel::experiments::{self, Effort};
use ecokernel::runtime::ArtifactRegistry;
use ecokernel::search::run_search;
use ecokernel::store::{ShardedStore, TuningRecord, TuningStore};
use ecokernel::util::Json;
use ecokernel::workload::suites;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    };
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "search" => cmd_search(rest),
        "analyze" => cmd_analyze(rest),
        "serve" => cmd_serve(rest),
        "query" => cmd_query(rest),
        "bench" => cmd_bench(rest),
        "experiment" => cmd_experiment(rest),
        "cache" => cmd_cache(rest),
        "artifacts" => cmd_artifacts(rest),
        "gpus" => cmd_gpus(),
        "config" => {
            println!("{}", SearchConfig::default().to_toml());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}'\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
ecokernel — search-based energy-efficient GPU kernel generation

USAGE:
  ecokernel search --workload <MM1|..|CONV3> [--gpu a100] [--mode energy|latency|nvml]
                   [--rounds N] [--population P] [--m M] [--mu DB] [--seed S]
                   [--store DIR] [--no-transfer]
                   [--config file.toml] [--events out.jsonl] [--json]
  ecokernel analyze --workload <MM1|..|CONV3> [--gpu a100] [--top N]
                   (no search, no measurements: deterministic static
                   profiles — the serve daemon's static-tier ranking)
  ecokernel serve  --store DIR --listen ADDR [--config file.toml] [--workers N]
                   [--shards N] [--quota N] [--max-records N] [--events out.jsonl]
                   (ADDR: unix:/path.sock or tcp:HOST:PORT; --socket PATH = unix)
  ecokernel query  --addr ADDR (--workload MM1 [--gpu a100] [--mode energy]
                   [--wait] [--timeout S] | --batch MM1,MV3,.. | --stats
                   | --metrics [--prom] | --health | --trace [--slowest N]
                   | --shutdown) [--json]
                   (--batch sends every workload in ONE frame / one
                   socket write; replies are positionally matched.
                   --metrics accepts --addr A,B,.. and merges the
                   fleet's histograms; --prom prints Prometheus text.
                   --health evaluates the [slo] targets (also fleet-
                   merged worst-of over --addr A,B,..) and prints the
                   drift watchdog's state.
                   --trace prints the daemon's retained request traces,
                   slowest first; --slowest N keeps the top N)
  ecokernel bench  serve [--quick] [--requests N] [--zipf S] [--batch N]
                   [--no-fleet] [--wire line|binary|both] [--out BENCH_serving.json]
                   (--wire picks the replay wire: the forever-compat
                   line-JSON framing, the hello-negotiated binary
                   framing, or both back-to-back for comparison)
  ecokernel experiment <table1..table5|fig2..fig5|warmcold|all> [--paper]
  ecokernel cache <stats|list|prune|export> --store DIR
  ecokernel artifacts [--dir artifacts] [--list | --check | --run WORKLOAD_ID [--variant ID]]
  ecokernel gpus
  ecokernel config";

/// Minimal flag parser: --key value / --key (boolean).
struct Flags {
    map: std::collections::HashMap<String, String>,
}

impl Flags {
    fn parse(args: &[String], bool_flags: &[&str]) -> anyhow::Result<Flags> {
        let mut map = std::collections::HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got '{a}'"))?;
            if bool_flags.contains(&key) {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                let v = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?;
                map.insert(key.to_string(), v.clone());
                i += 2;
            }
        }
        Ok(Flags { map })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse '{v}'")),
        }
    }
}

fn cmd_search(args: &[String]) -> anyhow::Result<()> {
    let flags = Flags::parse(args, &["json", "no-transfer"])?;
    let mut cfg = match flags.get("config") {
        Some(path) => SearchConfig::from_toml_file(std::path::Path::new(path))?,
        None => SearchConfig::default(),
    };
    if let Some(g) = flags.get("gpu") {
        cfg.gpu = GpuArch::parse(g).ok_or_else(|| anyhow::anyhow!("unknown gpu '{g}'"))?;
    }
    if let Some(m) = flags.get("mode") {
        cfg.mode = SearchMode::parse(m).ok_or_else(|| anyhow::anyhow!("unknown mode '{m}'"))?;
    }
    if let Some(r) = flags.parse_num::<usize>("rounds")? {
        cfg.rounds = r;
    }
    if let Some(p) = flags.parse_num::<usize>("population")? {
        cfg.population = p;
    }
    if let Some(m) = flags.parse_num::<usize>("m")? {
        cfg.m_latency_keep = m;
    }
    if let Some(mu) = flags.parse_num::<f64>("mu")? {
        cfg.mu_snr_db = mu;
    }
    if let Some(s) = flags.parse_num::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(dir) = flags.get("store") {
        cfg.store.dir = Some(dir.to_string());
    }
    if flags.has("no-transfer") {
        cfg.store.transfer = false;
    }
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;

    let wname = flags
        .get("workload")
        .ok_or_else(|| anyhow::anyhow!("--workload is required (e.g. MM1)"))?;
    let workload = suites::by_name(wname).ok_or_else(|| {
        anyhow::anyhow!("unknown workload '{wname}' (MM1..MM4, MV1..MV4, CONV1..CONV3)")
    })?;

    let out = if let Some(events) = flags.get("events") {
        let log = EventLog::to_file(std::path::Path::new(events))?;
        let driver = Driver::new(DriverConfig { n_workers: 1, queue_cap: 1 }).with_log(log);
        let (mut results, _) = driver.run_suite(vec![ecokernel::coordinator::SearchJob {
            name: wname.to_string(),
            workload,
            cfg: cfg.clone(),
        }]);
        results.remove(0).outcome
    } else {
        run_search(workload, &cfg)
    };

    if flags.has("json") {
        let obj = Json::obj(vec![
            ("workload", Json::str(workload.to_string())),
            ("gpu", Json::str(cfg.gpu.name())),
            ("mode", Json::str(cfg.mode.name())),
            ("schedule", Json::str(out.best.schedule.to_string())),
            ("variant_id", Json::str(out.best.schedule.variant_id())),
            ("latency_ms", Json::num(out.best.latency_s * 1e3)),
            ("energy_mj", Json::num(out.best.energy_j * 1e3)),
            ("power_w", Json::num(out.best.avg_power_w)),
            ("rounds", Json::num(out.rounds.len() as f64)),
            ("n_energy_measurements", Json::num(out.n_energy_measurements() as f64)),
            ("sim_time_s", Json::num(out.clock.total_s)),
        ]);
        println!("{obj}");
    } else {
        println!("workload  : {workload} on {} [{}]", cfg.gpu, cfg.mode.name());
        println!("best      : {}", out.best.schedule);
        println!("variant   : {}", out.best.schedule.variant_id());
        println!("latency   : {:.4} ms", out.best.latency_s * 1e3);
        println!("energy    : {:.3} mJ", out.best.energy_j * 1e3);
        println!("power     : {:.1} W", out.best.avg_power_w);
        println!(
            "search    : {} rounds, {} energy measurements, {:.1}s simulated",
            out.rounds.len(),
            out.n_energy_measurements(),
            out.clock.total_s
        );
        if !out.k_trace.is_empty() {
            let trace: Vec<String> = out.k_trace.iter().map(|k| format!("{k:.1}")).collect();
            println!("k trace   : {}", trace.join(" "));
        }
    }
    Ok(())
}

/// `ecokernel analyze`: the static analyzer standalone. Ranks the
/// workload's legal schedule space by closed-form static energy
/// ([`ecokernel::analysis`]) and prints the top-N profiles as one
/// deterministic JSON object — no search, no simulator run, no
/// measurements, so two invocations are byte-identical (CI pins this).
fn cmd_analyze(args: &[String]) -> anyhow::Result<()> {
    let flags = Flags::parse(args, &[])?;
    let wname = flags
        .get("workload")
        .ok_or_else(|| anyhow::anyhow!("--workload is required (e.g. MM1)"))?;
    let workload = suites::by_name(wname).ok_or_else(|| {
        anyhow::anyhow!("unknown workload '{wname}' (MM1..MM4, MV1..MV4, CONV1..CONV3)")
    })?;
    let gpu = match flags.get("gpu") {
        Some(g) => GpuArch::parse(g).ok_or_else(|| anyhow::anyhow!("unknown gpu '{g}'"))?,
        None => GpuArch::A100,
    };
    let top = flags.parse_num::<usize>("top")?.unwrap_or(1);
    let spec = gpu.spec();
    let ranked = ecokernel::analysis::rank_static(workload, &spec, top);
    let entries = ranked.iter().map(|(s, p)| {
        Json::obj(vec![
            ("schedule", ecokernel::store::record::schedule_to_json(s)),
            ("variant_id", Json::str(s.variant_id())),
            ("profile", p.to_json()),
        ])
    });
    let obj = Json::obj(vec![
        ("workload", Json::str(workload.id())),
        ("gpu", Json::str(gpu.name())),
        ("n_ranked", Json::num(ranked.len() as f64)),
        ("ranked", Json::arr(entries)),
    ]);
    println!("{obj}");
    Ok(())
}

/// Exactly one daemon address from `--listen`/`--addr` (`unix:`/`tcp:`
/// syntax) or the backward-compatible `--socket PATH` alias. Routed
/// through the shared [`ecokernel::serve::AddrList`] parser so a
/// malformed entry (or an accidental fleet list where one address is
/// expected) is named precisely.
#[cfg(unix)]
fn parse_addr_flags(flags: &Flags, primary: &str) -> anyhow::Result<ecokernel::serve::ServeAddr> {
    let raw = flags
        .get(primary)
        .or_else(|| flags.get("socket"))
        .ok_or_else(|| anyhow::anyhow!("--{primary} ADDR (or --socket PATH) is required"))?;
    ecokernel::serve::AddrList::parse(raw)
        .and_then(ecokernel::serve::AddrList::single)
        .map_err(anyhow::Error::msg)
}

/// A comma-separated fleet list from `--addr` (or the `--socket`
/// alias), via the same shared parser — parse errors name the
/// malformed entry by position.
#[cfg(unix)]
fn parse_fleet_flags(flags: &Flags) -> anyhow::Result<Vec<ecokernel::serve::ServeAddr>> {
    let raw = flags
        .get("addr")
        .or_else(|| flags.get("socket"))
        .ok_or_else(|| anyhow::anyhow!("--addr ADDR[,ADDR..] is required"))?;
    let list = ecokernel::serve::AddrList::parse(raw).map_err(anyhow::Error::msg)?;
    Ok(list.addrs)
}

/// Run the kernel-serving daemon (blocking until a `shutdown` request).
#[cfg(unix)]
fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    use ecokernel::serve::{Daemon, DaemonConfig};
    let flags = Flags::parse(args, &[])?;
    let mut cfg = match flags.get("config") {
        Some(path) => SearchConfig::from_toml_file(std::path::Path::new(path))?,
        None => SearchConfig::default(),
    };
    if let Some(n) = flags.parse_num::<usize>("workers")? {
        cfg.serve.n_workers = n;
    }
    if let Some(n) = flags.parse_num::<usize>("shards")? {
        cfg.serve.n_shards = n;
    }
    if let Some(n) = flags.parse_num::<usize>("quota")? {
        cfg.serve.per_gpu_quota = n;
    }
    if let Some(n) = flags.parse_num::<usize>("max-records")? {
        cfg.serve.max_records = n;
    }
    cfg.validate().map_err(anyhow::Error::msg)?;
    let store_dir = flags
        .get("store")
        .ok_or_else(|| anyhow::anyhow!("--store DIR is required"))?;
    let addr = parse_addr_flags(&flags, "listen")?;
    let log = match flags.get("events") {
        Some(path) => Some(EventLog::to_file(std::path::Path::new(path))?),
        None => None,
    };
    let daemon = Daemon::bind(
        DaemonConfig {
            addr,
            store_dir: std::path::PathBuf::from(store_dir),
            search: cfg.clone(),
        },
        log,
    )?;
    println!(
        "serving on {} (store {store_dir}, {} shards, {} workers; stop with \
         `ecokernel query --addr {} --shutdown`)",
        daemon.addr(),
        cfg.serve.n_shards,
        cfg.serve.n_workers,
        daemon.addr()
    );
    daemon.run()
}

#[cfg(not(unix))]
fn cmd_serve(_args: &[String]) -> anyhow::Result<()> {
    anyhow::bail!("`ecokernel serve` needs a Unix socket runtime (unix-only)")
}

/// Talk to a running daemon: get a kernel, read stats, or shut it down.
#[cfg(unix)]
fn cmd_query(args: &[String]) -> anyhow::Result<()> {
    use ecokernel::serve::{Op, ServeClient};
    let flags = Flags::parse(
        args,
        &["json", "wait", "stats", "shutdown", "metrics", "prom", "trace", "health"],
    )?;
    if flags.has("metrics") {
        // Handled before the single connect: `--addr` may be a
        // comma-separated fleet whose histograms merge client-side.
        return query_metrics(&flags);
    }
    if flags.has("health") {
        // Same fleet-address contract as --metrics: worst-of merge.
        return query_health(&flags);
    }
    let addr = parse_addr_flags(&flags, "addr")?;
    let mut client = ServeClient::connect(&addr)?;

    if flags.has("trace") {
        let slowest = flags.parse_num::<usize>("slowest")?.unwrap_or(0);
        let tr = client.call(Op::Traces { slowest })?.into_traces()?;
        if flags.has("json") {
            println!("{}", tr.to_json());
            return Ok(());
        }
        if tr.traces.is_empty() {
            println!("no completed traces retained (the ring holds miss chains only)");
        }
        for t in &tr.traces {
            println!(
                "trace {} key={} req={}{}{} total {:.3} ms",
                t.id.to_hex(),
                t.key,
                if t.req.is_empty() { "-" } else { t.req.as_str() },
                if t.remote { " [remote]" } else { "" },
                if t.error { " [error]" } else { "" },
                t.total_s * 1e3,
            );
            for s in &t.spans {
                let mut attrs = String::new();
                if let Some(r) = s.round {
                    attrs.push_str(&format!(" round={r}"));
                }
                if let Some(v) = s.snr_db {
                    attrs.push_str(&format!(" snr={v:.1}dB"));
                }
                if let Some(v) = s.relerr {
                    attrs.push_str(&format!(" relerr={v:.3}"));
                }
                if let Some(v) = s.k {
                    attrs.push_str(&format!(" k={v:.1}"));
                }
                if let Some(v) = s.n_measured {
                    attrs.push_str(&format!(" measured={v}"));
                }
                if let Some(n) = &s.note {
                    attrs.push_str(&format!(" ({n})"));
                }
                println!(
                    "  {:<16} +{:9.3} ms  {:9.3} ms{attrs}",
                    s.name,
                    s.start_s * 1e3,
                    s.dur_s * 1e3
                );
            }
        }
        return Ok(());
    }
    if flags.has("stats") {
        let s = client.call(Op::Stats)?.into_stats()?;
        if flags.has("json") {
            println!("{}", s.to_json());
        } else {
            println!("requests    : {} ({} hits, {} misses)", s.n_requests, s.n_hits, s.n_misses);
            println!("hit rate    : {:.1}%", s.hit_rate * 100.0);
            println!(
                "reply time  : p50 {:.3} ms, p99 {:.3} ms (simulated)",
                s.p50_reply_s * 1e3,
                s.p99_reply_s * 1e3
            );
            println!(
                "queue depth : {} in pool ({} backlogged, {} keys pending)",
                s.queue_depth, s.backlog_len, s.pending_keys
            );
            println!("searches    : {} done, {} enqueued total", s.n_searches_done, s.n_enqueued);
            println!("admission   : {} shed, {} fleet-coalesced", s.n_shed, s.n_fleet_coalesced);
            println!("static tier : {} misses answered search-free", s.n_static_tier);
            println!(
                "write-backs : {} fenced, {} dropped",
                s.n_writebacks_fenced, s.n_writebacks_dropped
            );
            if s.n_batch_frames > 0 {
                println!(
                    "batching    : {} requests over {} frames ({:.1} per syscall)",
                    s.n_batch_requests,
                    s.n_batch_frames,
                    s.n_batch_requests as f64 / s.n_batch_frames as f64
                );
            }
            println!(
                "freshness   : {} notify refreshes, {} poll-fallback refreshes",
                s.n_notify_refresh, s.n_poll_refresh
            );
            println!(
                "store       : {} records in {} shards ({} evicted)",
                s.n_records, s.n_shards, s.n_evicted_records
            );
            if !s.shard_records.is_empty() {
                let sizes: Vec<String> = s.shard_records.iter().map(|n| n.to_string()).collect();
                println!("shard sizes : [{}]", sizes.join(" "));
            }
            if !s.heat_histogram.is_empty() {
                let buckets: Vec<String> =
                    s.heat_histogram.iter().map(|n| n.to_string()).collect();
                println!("key heat    : [{}] (log2 buckets, coldest first)", buckets.join(" "));
            }
            println!("paid        : {} NVML measurements", s.measurements_paid);
        }
        return Ok(());
    }
    if flags.has("shutdown") {
        client.shutdown()?;
        println!("daemon acknowledged shutdown");
        return Ok(());
    }

    let gpu = match flags.get("gpu") {
        Some(g) => Some(GpuArch::parse(g).ok_or_else(|| anyhow::anyhow!("unknown gpu '{g}'"))?),
        None => None,
    };
    let mode = match flags.get("mode") {
        Some(m) => {
            Some(SearchMode::parse(m).ok_or_else(|| anyhow::anyhow!("unknown mode '{m}'"))?)
        }
        None => None,
    };

    // Batched query: every listed workload in ONE frame (one socket
    // write), replies positionally matched.
    if let Some(spec) = flags.get("batch") {
        let mut requests: Vec<ecokernel::serve::BatchRequest> = Vec::new();
        for name in spec.split(',') {
            let name = name.trim();
            if name.is_empty() {
                continue;
            }
            let w = suites::by_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown workload '{name}' (MM1..MM4, MV1..MV4, CONV1..CONV3)")
            })?;
            requests.push((w, gpu, mode));
        }
        anyhow::ensure!(!requests.is_empty(), "--batch needs a comma-separated workload list");
        let n = requests.len();
        let replies = client.call(Op::Batch(requests.clone()))?.into_batch(n)?;
        if flags.has("json") {
            let entries = replies.iter().map(|r| match r {
                Ok(k) => k.to_json(),
                Err(e) => Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("code", Json::str(e.code.clone())),
                    ("message", Json::str(e.message.clone())),
                ]),
            });
            println!(
                "{}",
                Json::obj(vec![
                    ("n", Json::num(replies.len() as f64)),
                    ("replies", Json::arr(entries)),
                ])
            );
        } else {
            for ((w, _, _), reply) in requests.iter().zip(&replies) {
                match reply {
                    Ok(k) => println!(
                        "{:<24} {:4} [{}/{}]{}",
                        w.to_string(),
                        if k.hit { "hit" } else { "miss" },
                        k.source.name(),
                        k.tier.name(),
                        if k.enqueued { " (search enqueued)" } else { "" }
                    ),
                    Err(e) => println!("{:<24} error {e}", w.to_string()),
                }
            }
        }
        return Ok(());
    }

    let wname = flags
        .get("workload")
        .ok_or_else(|| {
            anyhow::anyhow!("--workload NAME (or --batch / --stats / --shutdown) is required")
        })?;
    let workload = suites::by_name(wname).ok_or_else(|| {
        anyhow::anyhow!("unknown workload '{wname}' (MM1..MM4, MV1..MV4, CONV1..CONV3)")
    })?;
    let reply = if flags.has("wait") {
        let timeout = flags.parse_num::<u64>("timeout")?.unwrap_or(300);
        client.get_kernel_wait(workload, gpu, mode, std::time::Duration::from_secs(timeout))?
    } else {
        client.call(Op::GetKernel { workload, gpu, mode, trace: None })?.into_kernel()?
    };
    if flags.has("json") {
        println!("{}", reply.to_json());
    } else {
        println!("workload  : {workload}");
        println!(
            "result    : {} (source: {}, tier: {})",
            if reply.hit { "hit" } else { "miss" },
            reply.source.name(),
            reply.tier.name()
        );
        println!("schedule  : {}", reply.schedule);
        println!("variant   : {}", reply.schedule.variant_id());
        if reply.hit {
            println!("latency   : {:.4} ms (measured)", reply.latency_s * 1e3);
            println!("energy    : {:.3} mJ (measured)", reply.energy_j * 1e3);
        } else if reply.energy_j > 0.0 {
            println!("latency   : ~{:.4} ms (estimate)", reply.latency_s * 1e3);
            println!("energy    : ~{:.3} mJ (estimate)", reply.energy_j * 1e3);
        }
        println!(
            "serving   : reply {:.3} ms simulated, queue depth {}{}",
            reply.reply_time_s * 1e3,
            reply.queue_depth,
            if reply.enqueued { ", background search enqueued" } else { "" }
        );
    }
    Ok(())
}

#[cfg(not(unix))]
fn cmd_query(_args: &[String]) -> anyhow::Result<()> {
    anyhow::bail!("`ecokernel query` needs a Unix socket runtime (unix-only)")
}

/// `query --metrics`: full telemetry (counters + reply-time and
/// per-stage histograms) from one daemon, or merged across a
/// comma-separated fleet.
#[cfg(unix)]
fn query_metrics(flags: &Flags) -> anyhow::Result<()> {
    use ecokernel::serve::merged_metrics;
    let addrs = parse_fleet_flags(flags)?;
    let fm = merged_metrics(&addrs)?;
    // A partial merge is still a merge: warn about every daemon that
    // did not answer (stderr, so --json/--prom output stays parseable)
    // instead of aborting the whole fleet view.
    for (a, e) in &fm.errors {
        eprintln!("warning: daemon {a} unreachable: {e}");
    }
    let m = &fm.merged;
    if flags.has("prom") {
        print!("{}", m.to_prometheus());
        return Ok(());
    }
    if flags.has("json") {
        println!("{}", m.to_json());
        return Ok(());
    }
    let total = m.counter("n_requests");
    let hits = m.counter("n_hits");
    let pct = if total > 0 { hits as f64 / total as f64 * 100.0 } else { 0.0 };
    println!(
        "daemons     : {} ({} answered, {} unreachable)",
        addrs.len(),
        addrs.len() - fm.errors.len(),
        fm.errors.len()
    );
    println!("requests    : {total} ({hits} hits, {pct:.1}%)");
    println!(
        "reply wall  : p50 {:.3} ms, p99 {:.3} ms ({} samples)",
        m.reply_wall_s.quantile(50.0) * 1e3,
        m.reply_wall_s.quantile(99.0) * 1e3,
        m.reply_wall_s.count()
    );
    println!(
        "reply sim   : p50 {:.3} ms, p99 {:.3} ms",
        m.reply_sim_s.quantile(50.0) * 1e3,
        m.reply_sim_s.quantile(99.0) * 1e3
    );
    if m.counter("n_batch_frames") > 0 {
        println!("frames/write: {:.1}", m.frames_per_syscall());
    }
    println!("stages (wall-clock):");
    for (name, h) in &m.stages {
        if h.is_empty() {
            continue;
        }
        println!(
            "  {name:<16} n={:<8} p50={:.4} ms  p99={:.4} ms  mean={:.4} ms",
            h.count(),
            h.quantile(50.0) * 1e3,
            h.quantile(99.0) * 1e3,
            h.mean() * 1e3
        );
    }
    if !m.model.is_empty() {
        println!("cost model accuracy (family/regime):");
        for (key, h) in &m.model {
            println!(
                "  {key:<28} n={:<8} p50={:.3}  p99={:.3}  mean={:.3}",
                h.count(),
                h.quantile(50.0),
                h.quantile(99.0),
                h.mean()
            );
        }
    }
    Ok(())
}

/// `query --health`: SLO verdicts + drift-watchdog state from one
/// daemon, or the worst-of-per-target merge across a comma-separated
/// fleet (plus a synthesized `fleet_reachability` target that goes
/// critical naming every unreachable address).
#[cfg(unix)]
fn query_health(flags: &Flags) -> anyhow::Result<()> {
    use ecokernel::serve::merged_health;
    let addrs = parse_fleet_flags(flags)?;
    let fh = merged_health(&addrs)?;
    for (a, e) in &fh.errors {
        eprintln!("warning: daemon {a} unreachable: {e}");
    }
    let h = &fh.merged;
    if flags.has("json") {
        println!("{}", h.to_json());
        return Ok(());
    }
    println!("status      : {}", h.status.name());
    for t in &h.targets {
        let value = format!("{:.4}", t.value);
        println!("  {:<18} {:<8} {value:<10} {}", t.name, t.status.name(), t.reason);
    }
    let d = &h.drift;
    println!(
        "drift       : {} ({} re-searches, steady relerr {:.4}, fast {:.4}, budget {}/interval)",
        if d.drifting { "DRIFTING" } else { "stable" },
        d.n_drift_researches,
        d.relerr_steady_mean,
        d.relerr_fast_mean,
        d.budget
    );
    Ok(())
}

/// `bench serve`: the serving benchmark harness behind
/// `BENCH_serving.json` (spawns its own daemons; see
/// [`ecokernel::serve::bench`]).
#[cfg(unix)]
fn cmd_bench(args: &[String]) -> anyhow::Result<()> {
    use ecokernel::serve::{run_bench_serve, BenchServeOpts};
    let Some(what) = args.first() else {
        anyhow::bail!("bench target required: serve");
    };
    anyhow::ensure!(what == "serve", "unknown bench target '{what}' (expected: serve)");
    let flags = Flags::parse(&args[1..], &["quick", "no-fleet"])?;
    let mut opts = BenchServeOpts::default();
    if let Some(n) = flags.parse_num::<usize>("requests")? {
        opts.requests = n;
    }
    if let Some(z) = flags.parse_num::<f64>("zipf")? {
        opts.zipf_s = z;
    }
    if let Some(b) = flags.parse_num::<usize>("batch")? {
        opts.batch = b;
    }
    if flags.has("no-fleet") {
        opts.fleet = false;
    }
    if let Some(w) = flags.get("wire") {
        anyhow::ensure!(
            matches!(w, "line" | "binary" | "both"),
            "--wire must be line, binary, or both (got '{w}')"
        );
        opts.wire = w.to_string();
    }
    opts.quick = flags.has("quick");
    if let Some(o) = flags.get("out") {
        opts.out = std::path::PathBuf::from(o);
    }
    let t0 = std::time::Instant::now();
    let json = run_bench_serve(&opts)?;
    println!("{json}");
    eprintln!(
        "bench serve: wrote {} in {:.1}s wall",
        opts.out.display(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

#[cfg(not(unix))]
fn cmd_bench(_args: &[String]) -> anyhow::Result<()> {
    anyhow::bail!("`ecokernel bench` needs a Unix socket runtime (unix-only)")
}

fn cmd_experiment(args: &[String]) -> anyhow::Result<()> {
    let Some(id) = args.first() else {
        anyhow::bail!("experiment id required: table1..table5, fig2..fig5, all");
    };
    let flags = Flags::parse(&args[1..], &["paper", "quick"])?;
    let effort = if flags.has("paper") { Effort::Paper } else { Effort::Quick };
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL_IDS.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let text = experiments::run_by_id(id, effort)?;
        println!("{text}");
        println!("[{id} done in {:.1}s wall]\n", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_cache(args: &[String]) -> anyhow::Result<()> {
    let Some(action) = args.first() else {
        anyhow::bail!("cache action required: stats, list, prune, export");
    };
    let flags = Flags::parse(&args[1..], &[])?;
    let dir = flags
        .get("store")
        .ok_or_else(|| anyhow::anyhow!("--store DIR is required"))?;
    let dir = std::path::Path::new(dir);

    // A serve-daemon store (shards/ layout) reads through ShardedStore;
    // a classic single-file store through TuningStore.
    let sharded = dir.join(ecokernel::store::sharded::SHARDS_DIR)
        .join(ecokernel::store::sharded::META_FILE)
        .exists();
    if sharded {
        let store = ShardedStore::open_existing(dir)?;
        match action.as_str() {
            "stats" => {
                let s = store.stats();
                println!("store     : {:?} (sharded, {} shards)", store.dir(), store.n_shards());
                println!("records   : {}", s.n_records);
                println!("workloads : {}", s.n_workloads);
                println!("keys      : {}", s.n_keys);
                println!("paid      : {} energy measurements", s.total_energy_measurements);
                println!("saved/hit : {:.1}s simulated search time", s.total_sim_time_s);
            }
            "list" => {
                for rec in store.records() {
                    print_record(&rec);
                }
                if store.is_empty() {
                    println!("(store is empty)");
                }
            }
            "export" => {
                for rec in store.records() {
                    println!("{}", rec.to_json());
                }
            }
            "prune" => anyhow::bail!(
                "sharded stores are compacted by the daemon's eviction quotas \
                 ([serve] per_gpu_quota / max_records), not by `cache prune`"
            ),
            other => anyhow::bail!("unknown cache action '{other}' (stats, list, prune, export)"),
        }
        return Ok(());
    }

    let mut store = TuningStore::open(dir)?;
    match action.as_str() {
        "stats" => {
            let s = store.stats();
            println!("store     : {:?}", store.dir());
            println!("records   : {}", s.n_records);
            println!("workloads : {}", s.n_workloads);
            println!("keys      : {}", s.n_keys);
            println!("paid      : {} energy measurements", s.total_energy_measurements);
            println!("saved/hit : {:.1}s simulated search time", s.total_sim_time_s);
        }
        "list" => {
            for rec in store.records() {
                print_record(rec.as_ref());
            }
            if store.is_empty() {
                println!("(store is empty)");
            }
        }
        "prune" => {
            let removed = store.prune()?;
            println!("pruned {removed} superseded records ({} kept)", store.len());
        }
        "export" => {
            for rec in store.records() {
                println!("{}", rec.to_json());
            }
        }
        other => anyhow::bail!("unknown cache action '{other}' (stats, list, prune, export)"),
    }
    Ok(())
}

fn print_record(rec: &TuningRecord) {
    println!(
        "{:<30} {:<8} {:<16} seed={:<4} E={:>8.3} mJ  lat={:>8.4} ms  meas={:<4} {}",
        rec.workload_id,
        rec.gpu,
        rec.mode,
        rec.seed,
        rec.best.energy_j * 1e3,
        rec.best.latency_s * 1e3,
        rec.n_energy_measurements,
        rec.best.schedule
    );
}

fn cmd_artifacts(args: &[String]) -> anyhow::Result<()> {
    let flags = Flags::parse(args, &["list", "check"])?;
    let dir = flags
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(ArtifactRegistry::default_dir);
    let reg = ArtifactRegistry::open(&dir)?;
    if flags.has("list") || (!flags.has("check") && !flags.has("run")) {
        println!("{} artifacts in {:?}:", reg.n_artifacts(), reg.dir);
        for wid in reg.workload_ids() {
            let variants: Vec<&str> =
                reg.variants(wid).iter().map(|m| m.variant_id.as_str()).collect();
            println!("  {wid}: {}", variants.join(" "));
        }
        return Ok(());
    }
    if flags.has("check") {
        // Compile every artifact and run it on ones-inputs.
        let mut n_ok = 0;
        for wid in reg.workload_ids() {
            for meta in reg.variants(wid) {
                let kernel = reg.load(meta)?;
                let inputs: Vec<(Vec<f32>, Vec<usize>)> = meta
                    .arg_shapes
                    .iter()
                    .map(|s| (vec![1.0f32; s.iter().product()], s.clone()))
                    .collect();
                let refs: Vec<(&[f32], &[usize])> =
                    inputs.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
                let out = kernel.run_f32(&refs)?;
                anyhow::ensure!(!out.is_empty(), "{}: empty output", meta.name());
                anyhow::ensure!(
                    out.iter().all(|v| v.is_finite()),
                    "{}: non-finite output",
                    meta.name()
                );
                n_ok += 1;
            }
        }
        println!("checked {n_ok} artifacts: all compile and execute");
        return Ok(());
    }
    if let Some(wid) = flags.get("run") {
        let meta = match flags.get("variant") {
            Some(v) => reg
                .get(wid, v)
                .ok_or_else(|| anyhow::anyhow!("no variant '{v}' for '{wid}'"))?,
            None => reg
                .variants(wid)
                .first()
                .ok_or_else(|| anyhow::anyhow!("no artifacts for '{wid}'"))?,
        };
        let kernel = reg.load(meta)?;
        let inputs: Vec<(Vec<f32>, Vec<usize>)> = meta
            .arg_shapes
            .iter()
            .map(|s| (vec![1.0f32; s.iter().product()], s.clone()))
            .collect();
        let refs: Vec<(&[f32], &[usize])> =
            inputs.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
        let t = kernel.time_once(&refs)?;
        println!(
            "{}: compiled in {:.2}s, executed in {:.4}s ({} inputs)",
            meta.name(),
            kernel.compile_time.as_secs_f64(),
            t,
            meta.arg_shapes.len()
        );
        return Ok(());
    }
    Ok(())
}

fn cmd_gpus() -> anyhow::Result<()> {
    for arch in GpuArch::ALL {
        let s = arch.spec();
        println!(
            "{:8} {:>3} SMs x {:>3} cores @ {:.2} GHz  peak {:>6.1} TFLOP/s  DRAM {:>6.0} GB/s  TDP {:>3.0} W",
            arch.name(),
            s.num_sms,
            s.cores_per_sm,
            s.sm_clock_ghz,
            s.peak_gflops() / 1e3,
            s.dram_bw_gbs,
            s.tdp_w
        );
    }
    Ok(())
}
