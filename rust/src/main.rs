//! `ecokernel` — CLI for the energy-efficient kernel generation
//! framework (leader entrypoint).
//!
//! Subcommands:
//!   search      run one kernel search (the paper's core loop)
//!   experiment  regenerate a paper table/figure (table1..5, fig2..5, all)
//!   cache       inspect / maintain a persistent tuning store
//!   artifacts   inspect / execute the AOT artifact registry
//!   gpus        list simulated GPU spec sheets
//!   config      print the default search config as TOML

use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
use ecokernel::coordinator::{Driver, DriverConfig, EventLog};
use ecokernel::experiments::{self, Effort};
use ecokernel::runtime::ArtifactRegistry;
use ecokernel::search::run_search;
use ecokernel::store::TuningStore;
use ecokernel::util::Json;
use ecokernel::workload::suites;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    };
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "search" => cmd_search(rest),
        "experiment" => cmd_experiment(rest),
        "cache" => cmd_cache(rest),
        "artifacts" => cmd_artifacts(rest),
        "gpus" => cmd_gpus(),
        "config" => {
            println!("{}", SearchConfig::default().to_toml());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}'\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
ecokernel — search-based energy-efficient GPU kernel generation

USAGE:
  ecokernel search --workload <MM1|..|CONV3> [--gpu a100] [--mode energy|latency|nvml]
                   [--rounds N] [--population P] [--m M] [--mu DB] [--seed S]
                   [--store DIR] [--no-transfer]
                   [--config file.toml] [--events out.jsonl] [--json]
  ecokernel experiment <table1..table5|fig2..fig5|warmcold|all> [--paper]
  ecokernel cache <stats|list|prune|export> --store DIR
  ecokernel artifacts [--dir artifacts] [--list | --check | --run WORKLOAD_ID [--variant ID]]
  ecokernel gpus
  ecokernel config";

/// Minimal flag parser: --key value / --key (boolean).
struct Flags {
    map: std::collections::HashMap<String, String>,
}

impl Flags {
    fn parse(args: &[String], bool_flags: &[&str]) -> anyhow::Result<Flags> {
        let mut map = std::collections::HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got '{a}'"))?;
            if bool_flags.contains(&key) {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                let v = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?;
                map.insert(key.to_string(), v.clone());
                i += 2;
            }
        }
        Ok(Flags { map })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse '{v}'")),
        }
    }
}

fn cmd_search(args: &[String]) -> anyhow::Result<()> {
    let flags = Flags::parse(args, &["json", "no-transfer"])?;
    let mut cfg = match flags.get("config") {
        Some(path) => SearchConfig::from_toml_file(std::path::Path::new(path))?,
        None => SearchConfig::default(),
    };
    if let Some(g) = flags.get("gpu") {
        cfg.gpu = GpuArch::parse(g).ok_or_else(|| anyhow::anyhow!("unknown gpu '{g}'"))?;
    }
    if let Some(m) = flags.get("mode") {
        cfg.mode = SearchMode::parse(m).ok_or_else(|| anyhow::anyhow!("unknown mode '{m}'"))?;
    }
    if let Some(r) = flags.parse_num::<usize>("rounds")? {
        cfg.rounds = r;
    }
    if let Some(p) = flags.parse_num::<usize>("population")? {
        cfg.population = p;
    }
    if let Some(m) = flags.parse_num::<usize>("m")? {
        cfg.m_latency_keep = m;
    }
    if let Some(mu) = flags.parse_num::<f64>("mu")? {
        cfg.mu_snr_db = mu;
    }
    if let Some(s) = flags.parse_num::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(dir) = flags.get("store") {
        cfg.store.dir = Some(dir.to_string());
    }
    if flags.has("no-transfer") {
        cfg.store.transfer = false;
    }
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;

    let wname = flags
        .get("workload")
        .ok_or_else(|| anyhow::anyhow!("--workload is required (e.g. MM1)"))?;
    let workload = suites::by_name(wname).ok_or_else(|| {
        anyhow::anyhow!("unknown workload '{wname}' (MM1..MM4, MV1..MV4, CONV1..CONV3)")
    })?;

    let out = if let Some(events) = flags.get("events") {
        let log = EventLog::to_file(std::path::Path::new(events))?;
        let driver = Driver::new(DriverConfig { n_workers: 1, queue_cap: 1 }).with_log(log);
        let (mut results, _) = driver.run_suite(vec![ecokernel::coordinator::SearchJob {
            name: wname.to_string(),
            workload,
            cfg: cfg.clone(),
        }]);
        results.remove(0).outcome
    } else {
        run_search(workload, &cfg)
    };

    if flags.has("json") {
        let obj = Json::obj(vec![
            ("workload", Json::str(workload.to_string())),
            ("gpu", Json::str(cfg.gpu.name())),
            ("mode", Json::str(cfg.mode.name())),
            ("schedule", Json::str(out.best.schedule.to_string())),
            ("variant_id", Json::str(out.best.schedule.variant_id())),
            ("latency_ms", Json::num(out.best.latency_s * 1e3)),
            ("energy_mj", Json::num(out.best.energy_j * 1e3)),
            ("power_w", Json::num(out.best.avg_power_w)),
            ("rounds", Json::num(out.rounds.len() as f64)),
            ("n_energy_measurements", Json::num(out.n_energy_measurements() as f64)),
            ("sim_time_s", Json::num(out.clock.total_s)),
        ]);
        println!("{}", obj.to_string());
    } else {
        println!("workload  : {workload} on {} [{}]", cfg.gpu, cfg.mode.name());
        println!("best      : {}", out.best.schedule);
        println!("variant   : {}", out.best.schedule.variant_id());
        println!("latency   : {:.4} ms", out.best.latency_s * 1e3);
        println!("energy    : {:.3} mJ", out.best.energy_j * 1e3);
        println!("power     : {:.1} W", out.best.avg_power_w);
        println!(
            "search    : {} rounds, {} energy measurements, {:.1}s simulated",
            out.rounds.len(),
            out.n_energy_measurements(),
            out.clock.total_s
        );
        if !out.k_trace.is_empty() {
            let trace: Vec<String> = out.k_trace.iter().map(|k| format!("{k:.1}")).collect();
            println!("k trace   : {}", trace.join(" "));
        }
    }
    Ok(())
}

fn cmd_experiment(args: &[String]) -> anyhow::Result<()> {
    let Some(id) = args.first() else {
        anyhow::bail!("experiment id required: table1..table5, fig2..fig5, all");
    };
    let flags = Flags::parse(&args[1..], &["paper", "quick"])?;
    let effort = if flags.has("paper") { Effort::Paper } else { Effort::Quick };
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL_IDS.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let text = experiments::run_by_id(id, effort)?;
        println!("{text}");
        println!("[{id} done in {:.1}s wall]\n", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_cache(args: &[String]) -> anyhow::Result<()> {
    let Some(action) = args.first() else {
        anyhow::bail!("cache action required: stats, list, prune, export");
    };
    let flags = Flags::parse(&args[1..], &[])?;
    let dir = flags
        .get("store")
        .ok_or_else(|| anyhow::anyhow!("--store DIR is required"))?;
    let mut store = TuningStore::open(std::path::Path::new(dir))?;
    match action.as_str() {
        "stats" => {
            let s = store.stats();
            println!("store     : {:?}", store.dir());
            println!("records   : {}", s.n_records);
            println!("workloads : {}", s.n_workloads);
            println!("keys      : {}", s.n_keys);
            println!("paid      : {} energy measurements", s.total_energy_measurements);
            println!("saved/hit : {:.1}s simulated search time", s.total_sim_time_s);
        }
        "list" => {
            for rec in store.records() {
                println!(
                    "{:<30} {:<8} {:<16} seed={:<4} E={:>8.3} mJ  lat={:>8.4} ms  meas={:<4} {}",
                    rec.workload_id,
                    rec.gpu,
                    rec.mode,
                    rec.seed,
                    rec.best.energy_j * 1e3,
                    rec.best.latency_s * 1e3,
                    rec.n_energy_measurements,
                    rec.best.schedule
                );
            }
            if store.is_empty() {
                println!("(store is empty)");
            }
        }
        "prune" => {
            let removed = store.prune()?;
            println!("pruned {removed} superseded records ({} kept)", store.len());
        }
        "export" => {
            for rec in store.records() {
                println!("{}", rec.to_json().to_string());
            }
        }
        other => anyhow::bail!("unknown cache action '{other}' (stats, list, prune, export)"),
    }
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> anyhow::Result<()> {
    let flags = Flags::parse(args, &["list", "check"])?;
    let dir = flags
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(ArtifactRegistry::default_dir);
    let reg = ArtifactRegistry::open(&dir)?;
    if flags.has("list") || (!flags.has("check") && !flags.has("run")) {
        println!("{} artifacts in {:?}:", reg.n_artifacts(), reg.dir);
        for wid in reg.workload_ids() {
            let variants: Vec<&str> =
                reg.variants(wid).iter().map(|m| m.variant_id.as_str()).collect();
            println!("  {wid}: {}", variants.join(" "));
        }
        return Ok(());
    }
    if flags.has("check") {
        // Compile every artifact and run it on ones-inputs.
        let mut n_ok = 0;
        for wid in reg.workload_ids() {
            for meta in reg.variants(wid) {
                let kernel = reg.load(meta)?;
                let inputs: Vec<(Vec<f32>, Vec<usize>)> = meta
                    .arg_shapes
                    .iter()
                    .map(|s| (vec![1.0f32; s.iter().product()], s.clone()))
                    .collect();
                let refs: Vec<(&[f32], &[usize])> =
                    inputs.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
                let out = kernel.run_f32(&refs)?;
                anyhow::ensure!(!out.is_empty(), "{}: empty output", meta.name());
                anyhow::ensure!(
                    out.iter().all(|v| v.is_finite()),
                    "{}: non-finite output",
                    meta.name()
                );
                n_ok += 1;
            }
        }
        println!("checked {n_ok} artifacts: all compile and execute");
        return Ok(());
    }
    if let Some(wid) = flags.get("run") {
        let meta = match flags.get("variant") {
            Some(v) => reg
                .get(wid, v)
                .ok_or_else(|| anyhow::anyhow!("no variant '{v}' for '{wid}'"))?,
            None => reg
                .variants(wid)
                .first()
                .ok_or_else(|| anyhow::anyhow!("no artifacts for '{wid}'"))?,
        };
        let kernel = reg.load(meta)?;
        let inputs: Vec<(Vec<f32>, Vec<usize>)> = meta
            .arg_shapes
            .iter()
            .map(|s| (vec![1.0f32; s.iter().product()], s.clone()))
            .collect();
        let refs: Vec<(&[f32], &[usize])> =
            inputs.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
        let t = kernel.time_once(&refs)?;
        println!(
            "{}: compiled in {:.2}s, executed in {:.4}s ({} inputs)",
            meta.name(),
            kernel.compile_time.as_secs_f64(),
            t,
            meta.arg_shapes.len()
        );
        return Ok(());
    }
    Ok(())
}

fn cmd_gpus() -> anyhow::Result<()> {
    for arch in GpuArch::ALL {
        let s = arch.spec();
        println!(
            "{:8} {:>3} SMs x {:>3} cores @ {:.2} GHz  peak {:>6.1} TFLOP/s  DRAM {:>6.0} GB/s  TDP {:>3.0} W",
            arch.name(),
            s.num_sms,
            s.cores_per_sm,
            s.sm_clock_ghz,
            s.peak_gflops() / 1e3,
            s.dram_bw_gbs,
            s.tdp_w
        );
    }
    Ok(())
}
