//! High-level kernel feature extraction for the energy cost model
//! (§5.3–5.4).
//!
//! "These features include the number of floating-point and integer
//! operations, vectorization-related features, loop-related features,
//! and cache access features."
//!
//! Features are derived from the *schedule and loop structure only* —
//! never from the simulator's latency/power outputs — mirroring the
//! paper's setting where features come from static analysis of the
//! tensor program while energy comes from (slow) measurement. Counts are
//! log-compressed; ratio features are left linear.

pub mod extract;

pub use extract::{feature_names, featurize, FEATURE_DIM};

use crate::schedule::Candidate;

/// A fixed-width feature vector for one candidate kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureVector(pub [f64; FEATURE_DIM]);

impl FeatureVector {
    pub fn of(c: &Candidate, spec: &crate::config::GpuSpec) -> FeatureVector {
        featurize(c, spec)
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.0
    }
}
