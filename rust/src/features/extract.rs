//! The concrete feature map (§5.4).
//!
//! 40 features in six groups, mirroring the paper's taxonomy:
//! arithmetic (float/int op counts and densities), vectorization, loop
//! structure, cache/memory-access counts per level, launch/occupancy
//! geometry, and the static-analysis group (ISSUE 9) — roofline terms
//! from [`crate::analysis`] (arithmetic intensity, tile reuse,
//! predicted stall fraction, static latency). All *count* features are
//! `log1p`-compressed so the GBDT splits behave across the
//! 6-order-of-magnitude range between MV3 and MM4 kernels.
//!
//! The static group derives from geometry and bandwidth/peak-rate spec
//! fields only — never the energy coefficients — so the
//! `features_do_not_leak_energy` invariant below still holds: the
//! *target* must stay out of the inputs.

use super::FeatureVector;
use crate::config::GpuSpec;
use crate::schedule::Candidate;
use crate::sim::{occupancy, MemoryTraffic};

/// Number of features produced by [`featurize`].
pub const FEATURE_DIM: usize = 40;

/// Human-readable names, index-aligned with the vector.
pub fn feature_names() -> [&'static str; FEATURE_DIM] {
    [
        // arithmetic
        "log_flops",
        "log_int_ops",
        "flops_per_int_op",
        "log_macs_per_thread",
        "log_macs_per_block",
        // vectorization
        "vector_width",
        "vectorized_load_frac",
        "log_vector_loads",
        // loop structure
        "loop_depth",
        "log_k_steps",
        "unroll_k",
        "log_inner_iters",
        "tile_k",
        "split_k",
        // register tile
        "reg_m",
        "reg_n",
        "log_reg_tile_area",
        "regs_per_thread",
        // memory access counts
        "log_glb_ld_elems",
        "log_glb_st_txn",
        "log_shared_ld_txn",
        "log_shared_st_txn",
        "log_dram_bytes",
        "log_l2_bytes",
        "log_shared_bytes",
        "log_reg_bytes",
        "dram_reuse_factor",
        "shared_frac_of_traffic",
        // launch geometry / occupancy
        "log_grid",
        "log_threads_per_block",
        "blocks_per_sm",
        "occupancy",
        "active_sm_frac",
        "waves",
        "tail_efficiency",
        "uses_shared",
        // static analysis (roofline terms; no energy coefficients)
        "log_arith_intensity",
        "log_tile_reuse",
        "predicted_stall_frac",
        "log_static_latency_us",
    ]
}

/// Extract the feature vector for a candidate on an architecture.
///
/// Architecture enters only through *static* resource arithmetic
/// (occupancy limits, SM count) — the same information a compiler has
/// without running the kernel.
pub fn featurize(c: &Candidate, spec: &GpuSpec) -> FeatureVector {
    let s = &c.schedule;
    let g = c.gemm();
    let t = MemoryTraffic::compute(s, &g, spec);
    let grid = s.grid(&g);
    let occ = occupancy(s, grid, spec);
    let prof = crate::analysis::analyze(&c.workload, s, spec);

    let macs = g.macs() as f64;
    let flops = 2.0 * macs;
    let iops = crate::sim::latency::int_ops(s, &g);
    let tpb = s.threads_per_block() as f64;
    let k_steps = s.k_steps(&g) as f64;
    let inner_iters = k_steps * (s.tile_k / s.unroll_k) as f64;
    let vec_frac = if s.vector_width > 1 { 1.0 } else { 0.0 };
    let total_traffic = t.dram_bytes + t.l2_bytes + t.shared_bytes;
    let compulsory = (g.batch * (g.m * g.k + g.k * g.n + g.m * g.n) * 4) as f64;

    let f = [
        // arithmetic
        flops.ln_1p(),
        iops.ln_1p(),
        flops / (iops + 1.0),
        (macs / (grid as f64 * tpb)).ln_1p(),
        (macs / grid as f64).ln_1p(),
        // vectorization
        s.vector_width as f64,
        vec_frac,
        (t.glb_ld_elems / s.vector_width as f64).ln_1p(),
        // loop structure
        if g.batch > 1 { 5.0 } else { 4.0 },
        k_steps.ln_1p(),
        s.unroll_k as f64,
        inner_iters.ln_1p(),
        s.tile_k as f64,
        s.split_k as f64,
        // register tile
        s.reg_m as f64,
        s.reg_n as f64,
        ((s.reg_m * s.reg_n) as f64).ln_1p(),
        s.regs_per_thread() as f64,
        // memory access counts
        t.glb_ld_elems.ln_1p(),
        t.glb_st_txn.ln_1p(),
        t.shared_ld_txn.ln_1p(),
        t.shared_st_txn.ln_1p(),
        t.dram_bytes.ln_1p(),
        t.l2_bytes.ln_1p(),
        t.shared_bytes.ln_1p(),
        t.reg_bytes.ln_1p(),
        t.dram_bytes / compulsory.max(1.0),
        t.shared_bytes / total_traffic.max(1.0),
        // launch geometry / occupancy
        (grid as f64).ln_1p(),
        tpb.ln_1p(),
        occ.blocks_per_sm as f64,
        occ.occupancy,
        occ.active_sms as f64 / spec.num_sms as f64,
        occ.waves as f64,
        occ.tail_efficiency,
        if s.use_shared { 1.0 } else { 0.0 },
        // static analysis (roofline terms; no energy coefficients)
        prof.arithmetic_intensity.ln_1p(),
        prof.tile_reuse_factor.ln_1p(),
        prof.predicted_stall_frac,
        (prof.static_latency_s * 1e6).ln_1p(),
    ];
    FeatureVector(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::config::GpuArch;
    use crate::schedule::space::ScheduleSpace;
    use crate::workload::suites;
    
    

    #[test]
    fn names_match_dim() {
        assert_eq!(feature_names().len(), FEATURE_DIM);
    }

    #[test]
    fn features_finite_for_all_suites() {
        let mut rng = Rng::seed_from_u64(17);
        for arch in [GpuArch::A100, GpuArch::Rtx4090] {
            let spec = arch.spec();
            for (_, w) in suites::all_named() {
                let space = ScheduleSpace::new(w, &spec);
                for s in space.sample_n(&mut rng, 16) {
                    let fv = featurize(&Candidate::new(w, s), &spec);
                    for (i, v) in fv.0.iter().enumerate() {
                        assert!(v.is_finite(), "feature {i} not finite for {w}");
                    }
                }
            }
        }
    }

    #[test]
    fn different_schedules_have_different_features() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(5);
        let a = space.sample(&mut rng);
        let mut b = space.sample(&mut rng);
        while b == a {
            b = space.sample(&mut rng);
        }
        let fa = featurize(&Candidate::new(suites::MM1, a), &spec);
        let fb = featurize(&Candidate::new(suites::MM1, b), &spec);
        assert_ne!(fa.0, fb.0);
    }

    #[test]
    fn features_do_not_leak_energy() {
        // Deliberate design check: the feature map must be computable
        // without the power model. We assert the vector is unchanged if
        // we conceptually vary only energy coefficients (same spec
        // geometry, different energy table).
        let mut spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let s = space.fallback();
        let c = Candidate::new(suites::MM1, s);
        let f1 = featurize(&c, &spec);
        spec.energy_per_dram_byte_pj *= 10.0;
        spec.energy_per_flop_pj *= 10.0;
        spec.static_power_full_w *= 2.0;
        let f2 = featurize(&c, &spec);
        assert_eq!(f1.0, f2.0);
    }
}
