//! Tile-factorization helpers: the discrete domains each schedule knob
//! ranges over, and factorization utilities shared by the per-family
//! spaces and the mutation operators.

/// Powers of two in `[lo, hi]` (inclusive).
pub fn pow2_range(lo: usize, hi: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut x = lo.next_power_of_two().max(1);
    while x <= hi {
        v.push(x);
        x *= 2;
    }
    v
}

/// All (a, b) pairs with a*b == n, a and b powers of two.
pub fn pow2_factor_pairs(n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut a = 1;
    while a <= n {
        if n % a == 0 {
            out.push((a, n / a));
        }
        a *= 2;
    }
    out
}

/// Snap `x` to the nearest member of a sorted domain.
pub fn snap(domain: &[usize], x: usize) -> usize {
    debug_assert!(!domain.is_empty());
    *domain
        .iter()
        .min_by_key(|&&d| d.abs_diff(x))
        .expect("non-empty domain")
}

/// Index of `x` in `domain`, or the nearest index.
pub fn nearest_index(domain: &[usize], x: usize) -> usize {
    domain
        .iter()
        .enumerate()
        .min_by_key(|(_, &d)| d.abs_diff(x))
        .map(|(i, _)| i)
        .expect("non-empty domain")
}

/// The discrete domain of every schedule knob for one GEMM view.
///
/// Domains are shape-aware: thread/register extents never exceed the
/// (power-of-two-rounded) problem extent, and `split_k` is only offered
/// when the reduction is deep enough to be worth splitting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KnobDomains {
    pub threads_m: Vec<usize>,
    pub threads_n: Vec<usize>,
    pub reg_m: Vec<usize>,
    pub reg_n: Vec<usize>,
    pub tile_k: Vec<usize>,
    pub unroll_k: Vec<usize>,
    pub vector_width: Vec<usize>,
    pub split_k: Vec<usize>,
    pub use_shared: Vec<bool>,
}

impl KnobDomains {
    /// Upper bound on the number of distinct schedules (cartesian size).
    pub fn cardinality(&self) -> u128 {
        [
            self.threads_m.len(),
            self.threads_n.len(),
            self.reg_m.len(),
            self.reg_n.len(),
            self.tile_k.len(),
            self.unroll_k.len(),
            self.vector_width.len(),
            self.split_k.len(),
            self.use_shared.len(),
        ]
        .iter()
        .map(|&l| l as u128)
        .product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_ranges() {
        assert_eq!(pow2_range(1, 8), vec![1, 2, 4, 8]);
        assert_eq!(pow2_range(4, 4), vec![4]);
        assert_eq!(pow2_range(3, 8), vec![4, 8]);
        assert!(pow2_range(16, 8).is_empty());
    }

    #[test]
    fn factor_pairs() {
        assert_eq!(pow2_factor_pairs(8), vec![(1, 8), (2, 4), (4, 2), (8, 1)]);
    }

    #[test]
    fn snapping() {
        let d = vec![1, 2, 4, 8, 16];
        assert_eq!(snap(&d, 5), 4);
        assert_eq!(snap(&d, 100), 16);
        assert_eq!(nearest_index(&d, 7), 3);
    }
}
