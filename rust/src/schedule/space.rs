//! Per-operator-family schedule spaces: knob domains, random sampling,
//! and bounded enumeration.
//!
//! This is the Ansor "sketch + annotation" analogue: the *sketch* is the
//! tiled implicit-GEMM structure (fixed per family), the *annotations*
//! are the tile factors sampled from [`KnobDomains`].

use super::tiling::{pow2_range, KnobDomains};
use super::Schedule;
use crate::config::GpuSpec;
use crate::workload::{GemmView, Workload};
use crate::util::Rng;

/// The schedule space for one workload on one architecture.
#[derive(Debug, Clone)]
pub struct ScheduleSpace {
    pub workload: Workload,
    pub gemm: GemmView,
    pub domains: KnobDomains,
    spec: GpuSpec,
}

impl ScheduleSpace {
    /// Build the space for `workload` on `spec`.
    pub fn new(workload: Workload, spec: &GpuSpec) -> ScheduleSpace {
        let gemm = workload.gemm_view();
        let domains = domains_for(&gemm, spec);
        ScheduleSpace { workload, gemm, domains, spec: spec.clone() }
    }

    pub fn spec(&self) -> &GpuSpec {
        &self.spec
    }

    /// Sample one legal schedule uniformly over the knob domains
    /// (rejection sampling against the legality predicate).
    pub fn sample(&self, rng: &mut Rng) -> Schedule {
        let d = &self.domains;
        for _ in 0..10_000 {
            let s = Schedule {
                threads_m: *choose(rng, &d.threads_m),
                threads_n: *choose(rng, &d.threads_n),
                reg_m: *choose(rng, &d.reg_m),
                reg_n: *choose(rng, &d.reg_n),
                tile_k: *choose(rng, &d.tile_k),
                unroll_k: *choose(rng, &d.unroll_k),
                vector_width: *choose(rng, &d.vector_width),
                split_k: *choose(rng, &d.split_k),
                use_shared: *choose(rng, &d.use_shared),
            };
            if s.legal_for(&self.gemm, &self.spec) {
                return s;
            }
        }
        // The fallback schedule below is legal for every family/arch.
        self.fallback()
    }

    /// A conservative always-legal schedule (used as sampling fallback
    /// and as the deterministic seed candidate).
    pub fn fallback(&self) -> Schedule {
        let s = if self.gemm.m == 1 {
            Schedule {
                threads_m: 1,
                threads_n: 64,
                reg_m: 1,
                reg_n: 1,
                tile_k: 16,
                unroll_k: 4,
                vector_width: 1,
                split_k: 1,
                use_shared: true,
            }
        } else {
            Schedule {
                threads_m: 8,
                threads_n: 8,
                reg_m: 2,
                reg_n: 2,
                tile_k: 8,
                unroll_k: 4,
                vector_width: 1,
                split_k: 1,
                use_shared: true,
            }
        };
        debug_assert!(s.legal_for(&self.gemm, &self.spec));
        s
    }

    /// Sample `n` legal schedules (may contain duplicates — dedup is the
    /// population's job).
    pub fn sample_n(&self, rng: &mut Rng, n: usize) -> Vec<Schedule> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    /// Enumerate up to `cap` legal schedules deterministically (grid
    /// order). Used by Fig. 3's exhaustive latency-power sweep.
    pub fn enumerate(&self, cap: usize) -> Vec<Schedule> {
        let d = &self.domains;
        let mut out = Vec::new();
        'outer: for &tm in &d.threads_m {
            for &tn in &d.threads_n {
                for &rm in &d.reg_m {
                    for &rn in &d.reg_n {
                        for &tk in &d.tile_k {
                            for &uk in &d.unroll_k {
                                for &vw in &d.vector_width {
                                    for &sk in &d.split_k {
                                        for &sh in &d.use_shared {
                                            let s = Schedule {
                                                threads_m: tm,
                                                threads_n: tn,
                                                reg_m: rm,
                                                reg_n: rn,
                                                tile_k: tk,
                                                unroll_k: uk,
                                                vector_width: vw,
                                                split_k: sk,
                                                use_shared: sh,
                                            };
                                            if s.legal_for(&self.gemm, &self.spec) {
                                                out.push(s);
                                                if out.len() >= cap {
                                                    break 'outer;
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// True if `s` is legal in this space.
    pub fn is_legal(&self, s: &Schedule) -> bool {
        s.legal_for(&self.gemm, &self.spec)
    }
}

fn choose<'a, T>(rng: &mut Rng, v: &'a [T]) -> &'a T {
    &v[rng.gen_range(0, v.len())]
}

/// Shape- and family-aware knob domains.
pub fn domains_for(g: &GemmView, spec: &GpuSpec) -> KnobDomains {
    let max_tpb = spec.max_threads_per_block;
    if g.m == 1 {
        // MV family: one output row; all thread parallelism along N,
        // deep reductions benefit from split-k and streaming (no shared
        // staging of the vector operand).
        KnobDomains {
            threads_m: vec![1],
            threads_n: pow2_range(32, max_tpb.min(512)),
            reg_m: vec![1],
            reg_n: pow2_range(1, 8.min(g.n)),
            tile_k: pow2_range(8, 128.min(g.k.next_power_of_two())),
            unroll_k: pow2_range(1, 8),
            vector_width: vec![1, 2, 4],
            split_k: pow2_range(1, 64.min(g.k / 64).max(1)),
            use_shared: vec![true, false],
        }
    } else {
        // MM / Conv family: 2-D block tiles, register tiles for reuse.
        let m_cap = g.m.next_power_of_two().min(32);
        let n_cap = g.n.next_power_of_two().min(32);
        KnobDomains {
            threads_m: pow2_range(1, m_cap),
            threads_n: pow2_range(2, n_cap),
            reg_m: pow2_range(1, 8.min(g.m.next_power_of_two())),
            reg_n: pow2_range(1, 8.min(g.n.next_power_of_two())),
            tile_k: pow2_range(4, 64.min(g.k.next_power_of_two())),
            unroll_k: pow2_range(1, 8),
            vector_width: vec![1, 2, 4],
            split_k: if g.k >= 1024 { vec![1, 2, 4] } else { vec![1] },
            use_shared: vec![true],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::workload::suites;
    
    

    #[test]
    fn samples_are_legal_for_every_suite_workload() {
        let mut rng = Rng::seed_from_u64(7);
        for arch in [GpuArch::A100, GpuArch::Rtx4090, GpuArch::P100] {
            let spec = arch.spec();
            for (name, w) in suites::all_named() {
                let space = ScheduleSpace::new(w, &spec);
                for s in space.sample_n(&mut rng, 64) {
                    assert!(space.is_legal(&s), "{name} on {arch}: illegal sample {s}");
                }
            }
        }
    }

    #[test]
    fn space_is_large() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM2, &spec);
        // The paper's premise: a big exploration space (Table 1).
        assert!(space.domains.cardinality() > 10_000, "{}", space.domains.cardinality());
        let enumerated = space.enumerate(5_000);
        assert!(enumerated.len() > 500, "{}", enumerated.len());
    }

    #[test]
    fn enumerate_is_deterministic_and_legal() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let a = space.enumerate(300);
        let b = space.enumerate(300);
        assert_eq!(a, b);
        assert!(a.iter().all(|s| space.is_legal(s)));
    }

    #[test]
    fn mv_domains_pin_m_axis() {
        let spec = GpuArch::A100.spec();
        let d = domains_for(&suites::MV1.gemm_view(), &spec);
        assert_eq!(d.threads_m, vec![1]);
        assert_eq!(d.reg_m, vec![1]);
        assert!(d.split_k.len() > 1, "deep MV should offer split-k");
    }

    #[test]
    fn fallback_is_legal_everywhere() {
        for arch in GpuArch::ALL {
            let spec = arch.spec();
            for (_, w) in suites::all_named() {
                let space = ScheduleSpace::new(w, &spec);
                assert!(space.is_legal(&space.fallback()));
            }
        }
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let a = space.sample_n(&mut Rng::seed_from_u64(42), 20);
        let b = space.sample_n(&mut Rng::seed_from_u64(42), 20);
        assert_eq!(a, b);
    }
}
