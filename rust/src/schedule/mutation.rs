//! Genetic operators over schedules: knob-local mutation and two-parent
//! crossover (§4.4 "GeneticReproduction").
//!
//! Mutation moves a knob to an *adjacent* member of its domain (local
//! search in the tile lattice); crossover mixes whole axes (the M-axis
//! split of one parent with the N/K-axis split of the other), which
//! preserves per-axis legality structure.

use super::space::ScheduleSpace;
use super::tiling::nearest_index;
use super::Schedule;
use crate::util::Rng;

/// Which knob a mutation targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Knob {
    ThreadsM,
    ThreadsN,
    RegM,
    RegN,
    TileK,
    UnrollK,
    VectorWidth,
    SplitK,
    UseShared,
}

pub const ALL_KNOBS: [Knob; 9] = [
    Knob::ThreadsM,
    Knob::ThreadsN,
    Knob::RegM,
    Knob::RegN,
    Knob::TileK,
    Knob::UnrollK,
    Knob::VectorWidth,
    Knob::SplitK,
    Knob::UseShared,
];

/// Mutate one knob of `s` to an adjacent domain value. Returns a legal
/// schedule (falls back to `s` unchanged if no legal neighbour exists).
pub fn mutate_one(space: &ScheduleSpace, s: &Schedule, rng: &mut Rng) -> Schedule {
    // Try a few knobs before giving up; illegal proposals are rejected.
    for _ in 0..16 {
        let knob = ALL_KNOBS[rng.gen_range(0, ALL_KNOBS.len())];
        let proposal = step_knob(space, s, knob, rng);
        if proposal != *s && space.is_legal(&proposal) {
            return proposal;
        }
    }
    *s
}

/// Mutate each knob independently with probability `p`.
pub fn mutate(space: &ScheduleSpace, s: &Schedule, p: f64, rng: &mut Rng) -> Schedule {
    let mut out = *s;
    for &knob in &ALL_KNOBS {
        if rng.gen_bool(p) {
            let proposal = step_knob(space, &out, knob, rng);
            if space.is_legal(&proposal) {
                out = proposal;
            }
        }
    }
    out
}

/// Two-parent crossover: child takes the M-axis genes from `a`, the
/// N-axis genes from `b`, and each remaining gene from a random parent.
pub fn crossover(
    space: &ScheduleSpace,
    a: &Schedule,
    b: &Schedule,
    rng: &mut Rng,
) -> Schedule {
    let pick = |rng: &mut Rng, x: usize, y: usize| if rng.gen_bool(0.5) { x } else { y };
    let child = Schedule {
        threads_m: a.threads_m,
        reg_m: a.reg_m,
        threads_n: b.threads_n,
        reg_n: b.reg_n,
        tile_k: pick(rng, a.tile_k, b.tile_k),
        unroll_k: pick(rng, a.unroll_k, b.unroll_k),
        vector_width: pick(rng, a.vector_width, b.vector_width),
        split_k: pick(rng, a.split_k, b.split_k),
        use_shared: if rng.gen_bool(0.5) { a.use_shared } else { b.use_shared },
    };
    // Unroll must divide tile_k; repair instead of rejecting.
    let mut child = child;
    while child.tile_k % child.unroll_k != 0 {
        child.unroll_k /= 2;
    }
    if space.is_legal(&child) {
        child
    } else {
        *a
    }
}

fn step_knob(space: &ScheduleSpace, s: &Schedule, knob: Knob, rng: &mut Rng) -> Schedule {
    let d = &space.domains;
    let mut out = *s;
    match knob {
        Knob::ThreadsM => out.threads_m = step(&d.threads_m, s.threads_m, rng),
        Knob::ThreadsN => out.threads_n = step(&d.threads_n, s.threads_n, rng),
        Knob::RegM => out.reg_m = step(&d.reg_m, s.reg_m, rng),
        Knob::RegN => out.reg_n = step(&d.reg_n, s.reg_n, rng),
        Knob::TileK => out.tile_k = step(&d.tile_k, s.tile_k, rng),
        Knob::UnrollK => out.unroll_k = step(&d.unroll_k, s.unroll_k, rng),
        Knob::VectorWidth => out.vector_width = step(&d.vector_width, s.vector_width, rng),
        Knob::SplitK => out.split_k = step(&d.split_k, s.split_k, rng),
        Knob::UseShared => {
            if d.use_shared.len() > 1 {
                out.use_shared = !s.use_shared;
            }
        }
    }
    // Keep the unroll/tile_k divisibility invariant after any step.
    while out.tile_k % out.unroll_k != 0 {
        out.unroll_k /= 2;
    }
    out
}

/// Move to an adjacent value in the (sorted) domain.
fn step(domain: &[usize], cur: usize, rng: &mut Rng) -> usize {
    if domain.len() <= 1 {
        return cur;
    }
    let i = nearest_index(domain, cur);
    let j = if i == 0 {
        1
    } else if i == domain.len() - 1 {
        i - 1
    } else if rng.gen_bool(0.5) {
        i - 1
    } else {
        i + 1
    };
    domain[j]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::workload::suites;
    
    

    fn space() -> ScheduleSpace {
        ScheduleSpace::new(suites::MM1, &GpuArch::A100.spec())
    }

    #[test]
    fn mutations_stay_legal() {
        let space = space();
        let mut rng = Rng::seed_from_u64(1);
        let mut s = space.fallback();
        for _ in 0..500 {
            s = mutate_one(&space, &s, &mut rng);
            assert!(space.is_legal(&s), "illegal after mutation: {s}");
        }
    }

    #[test]
    fn mutation_actually_moves() {
        let space = space();
        let mut rng = Rng::seed_from_u64(2);
        let s = space.fallback();
        let mut moved = 0;
        for _ in 0..50 {
            if mutate_one(&space, &s, &mut rng) != s {
                moved += 1;
            }
        }
        assert!(moved > 40, "mutation should usually change the schedule ({moved}/50)");
    }

    #[test]
    fn crossover_stays_legal_and_mixes() {
        let space = space();
        let mut rng = Rng::seed_from_u64(3);
        let a = space.sample(&mut rng);
        let b = space.sample(&mut rng);
        for _ in 0..200 {
            let c = crossover(&space, &a, &b, &mut rng);
            assert!(space.is_legal(&c));
            assert_eq!(c.threads_m, a.threads_m, "M genes come from parent a");
            // N genes come from parent b unless repair fell back to a.
            if c != a {
                assert_eq!(c.threads_n, b.threads_n);
            }
        }
    }

    #[test]
    fn mv_mutations_respect_unit_m() {
        let space = ScheduleSpace::new(suites::MV3, &GpuArch::A100.spec());
        let mut rng = Rng::seed_from_u64(4);
        let mut s = space.fallback();
        for _ in 0..300 {
            s = mutate_one(&space, &s, &mut rng);
            assert_eq!(s.threads_m, 1);
            assert_eq!(s.reg_m, 1);
        }
    }
}
