//! Tensor-program schedule representation (the search space).
//!
//! Following Ansor's hierarchical decomposition (§2.2), every workload
//! lowers to an implicit GEMM (see [`crate::workload::GemmView`]) and a
//! [`Schedule`] fixes its GPU mapping:
//!
//! * a **thread-block tile** `(bm, bn)` where `bm = threads_m * reg_m`,
//!   `bn = threads_n * reg_n` — the block computes a `bm x bn` output
//!   tile with `threads_m * threads_n` threads, each holding a
//!   `reg_m x reg_n` register accumulator;
//! * a **reduction stage depth** `tile_k` — operand panels of shape
//!   `bm x tile_k` and `tile_k x bn` are staged in shared memory
//!   (VMEM, in the TPU adaptation) per k-step;
//! * `vector_width` — global-load vectorization (float1/2/4);
//! * `split_k` — reduction split across blocks (exposes parallelism for
//!   skinny GEMMs such as MV, at the price of an atomic/second-pass
//!   reduction);
//! * `unroll_k` — innermost unroll, affecting int-op overhead and ILP.
//!
//! The same knobs parameterize the L1 Pallas kernels, so any searched
//! schedule maps onto a compilable artifact (see `python/compile/`).

pub mod mutation;
pub mod space;
pub mod tiling;

use crate::config::GpuSpec;
use crate::workload::{GemmView, Workload};

/// A complete schedule for one workload's implicit GEMM.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Schedule {
    /// Threads along the M axis of the block tile.
    pub threads_m: usize,
    /// Threads along the N axis of the block tile.
    pub threads_n: usize,
    /// Register tile per thread along M.
    pub reg_m: usize,
    /// Register tile per thread along N.
    pub reg_n: usize,
    /// Shared-memory staging depth along K.
    pub tile_k: usize,
    /// Innermost unroll factor (divides `tile_k`).
    pub unroll_k: usize,
    /// Global-memory load vector width (floats per instruction).
    pub vector_width: usize,
    /// Reduction split across blocks (1 = none).
    pub split_k: usize,
    /// Stage operand panels in shared memory (false = stream from L2,
    /// only sensible for MV-like shapes).
    pub use_shared: bool,
}

impl Schedule {
    /// Block tile extent along M.
    pub fn block_m(&self) -> usize {
        self.threads_m * self.reg_m
    }

    /// Block tile extent along N.
    pub fn block_n(&self) -> usize {
        self.threads_n * self.reg_n
    }

    /// Threads per block.
    pub fn threads_per_block(&self) -> usize {
        self.threads_m * self.threads_n
    }

    /// Grid extent (blocks) for a GEMM view, including batch and split-k.
    pub fn grid(&self, g: &GemmView) -> usize {
        let gm = g.m.div_ceil(self.block_m());
        let gn = g.n.div_ceil(self.block_n());
        g.batch * gm * gn * self.split_k
    }

    /// K extent each block reduces over (after split-k).
    pub fn k_per_block(&self, g: &GemmView) -> usize {
        g.k.div_ceil(self.split_k)
    }

    /// Number of k-steps (shared-memory stages) per block.
    pub fn k_steps(&self, g: &GemmView) -> usize {
        self.k_per_block(g).div_ceil(self.tile_k)
    }

    /// Shared memory bytes per block (two FP32 operand panels, double
    /// buffered when staging is on).
    pub fn shared_bytes_per_block(&self) -> usize {
        if !self.use_shared {
            return 0;
        }
        let a_panel = self.block_m() * self.tile_k;
        let b_panel = self.tile_k * self.block_n();
        // Double buffering: overlap the next panel load with compute.
        2 * 4 * (a_panel + b_panel)
    }

    /// Estimated registers per thread: accumulator + operand fragments +
    /// addressing/bookkeeping.
    pub fn regs_per_thread(&self) -> usize {
        self.reg_m * self.reg_n + 2 * (self.reg_m + self.reg_n) + 24
    }

    /// Architecture legality: resource limits of `spec`.
    pub fn legal_on(&self, spec: &GpuSpec) -> bool {
        let tpb = self.threads_per_block();
        tpb >= 32
            && tpb <= spec.max_threads_per_block
            && self.shared_bytes_per_block() <= spec.max_shared_per_block
            && self.regs_per_thread() <= spec.max_regs_per_thread
            && self.unroll_k <= self.tile_k
            && self.tile_k % self.unroll_k == 0
            && matches!(self.vector_width, 1 | 2 | 4)
    }

    /// Workload legality: the tile must not be degenerate for the shape
    /// (block tiles no larger than 2x the padded problem extent, split-k
    /// must leave at least one stage of work).
    pub fn legal_for(&self, g: &GemmView, spec: &GpuSpec) -> bool {
        self.legal_on(spec)
            && self.block_m() <= 2 * g.m.next_power_of_two()
            && self.block_n() <= 2 * g.n.next_power_of_two()
            && self.k_per_block(g) >= self.tile_k.min(g.k)
            && self.split_k <= g.k
            // MV-style shapes (m == 1) must not spend threads on M.
            && (g.m > 1 || (self.threads_m == 1 && self.reg_m == 1))
            // vector loads must divide the contiguous extent
            && g.n % self.vector_width == 0
    }

    /// Stable identifier of the *artifact-relevant* part of the schedule
    /// (block tile geometry). Used to map a searched schedule onto one of
    /// the AOT-compiled Pallas variants.
    pub fn variant_id(&self) -> String {
        format!("bm{}_bn{}_bk{}", self.block_m(), self.block_n(), self.tile_k)
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "S[bm={}x{} bn={}x{} bk={} u={} v={} sk={} sh={}]",
            self.threads_m,
            self.reg_m,
            self.threads_n,
            self.reg_n,
            self.tile_k,
            self.unroll_k,
            self.vector_width,
            self.split_k,
            if self.use_shared { 1 } else { 0 }
        )
    }
}

/// A schedule bound to its workload — the unit the search evaluates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub workload: Workload,
    pub schedule: Schedule,
}

impl Candidate {
    pub fn new(workload: Workload, schedule: Schedule) -> Self {
        Candidate { workload, schedule }
    }

    pub fn gemm(&self) -> GemmView {
        self.workload.gemm_view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::workload::suites;

    fn basic() -> Schedule {
        Schedule {
            threads_m: 8,
            threads_n: 16,
            reg_m: 4,
            reg_n: 4,
            tile_k: 16,
            unroll_k: 4,
            vector_width: 4,
            split_k: 1,
            use_shared: true,
        }
    }

    #[test]
    fn derived_geometry() {
        let s = basic();
        assert_eq!(s.block_m(), 32);
        assert_eq!(s.block_n(), 64);
        assert_eq!(s.threads_per_block(), 128);
        let g = suites::MM1.gemm_view();
        assert_eq!(s.grid(&g), (512 / 32) * (512 / 64));
        assert_eq!(s.k_steps(&g), 512 / 16);
    }

    #[test]
    fn shared_bytes_double_buffered() {
        let s = basic();
        // (32*16 + 16*64) * 4B * 2 = 12288
        assert_eq!(s.shared_bytes_per_block(), 12288);
    }

    #[test]
    fn legality_checks() {
        let spec = GpuArch::A100.spec();
        let s = basic();
        assert!(s.legal_on(&spec));

        let mut too_many_threads = s;
        too_many_threads.threads_m = 64;
        too_many_threads.threads_n = 64;
        assert!(!too_many_threads.legal_on(&spec));

        let mut bad_unroll = s;
        bad_unroll.unroll_k = 3;
        assert!(!bad_unroll.legal_on(&spec));

        let mut huge_regs = s;
        huge_regs.reg_m = 16;
        huge_regs.reg_n = 16;
        assert!(!huge_regs.legal_on(&spec));
    }

    #[test]
    fn mv_legality_forces_unit_m() {
        let spec = GpuArch::A100.spec();
        let g = suites::MV3.gemm_view();
        let mut s = basic();
        assert!(!s.legal_for(&g, &spec), "threads_m>1 illegal for MV");
        s.threads_m = 1;
        s.reg_m = 1;
        s.threads_n = 128;
        assert!(s.legal_for(&g, &spec));
    }

    #[test]
    fn variant_id_is_block_geometry() {
        assert_eq!(basic().variant_id(), "bm32_bn64_bk16");
    }
}
