//! # ecokernel — energy-efficient GPU kernel generation
//!
//! A search-based compilation framework that generates tensor-program
//! kernels optimized for **both latency and energy**, reproducing
//! *"Automating Energy-Efficient GPU Kernel Generation: A Fast
//! Search-Based Compilation Approach"* (Zhang et al., 2024).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the search coordinator: schedule space,
//!   genetic search with latency-first/energy-second selection (§4), a
//!   from-scratch GBDT energy cost model (§5), the dynamic-k updating
//!   strategy (§6, Algorithm 1), plus the simulated GPU + NVML
//!   substrates that stand in for the paper's physical testbed.
//!   On top of the per-search loop sits the **tuning store** layer
//!   ([`store`]): an on-disk, append-only cache of finished searches.
//!   Repeat traffic is served as an exact cache hit (the recorded
//!   kernel, zero measurements); unseen workloads **warm-start** from
//!   their nearest cached neighbors — seeded genetic population,
//!   pre-trained cost model, transferred dynamic-k — so production
//!   deployments stop re-paying the full search cost per workload.
//!   [`coordinator`] consults the store before dispatching jobs to the
//!   worker pool and writes outcomes back after each search. The
//!   [`serve`] daemon puts that store behind a `get_kernel` socket API:
//!   exact hits reply instantly from a sharded, eviction-managed store;
//!   misses reply with a warm guess while a background search fills the
//!   cache for the next request.
//! * **L2/L1 (build-time Python)** — JAX + Pallas kernels parameterized
//!   by the same schedule knobs, AOT-lowered to HLO text in
//!   `artifacts/`.
//! * **Runtime** — [`runtime`] loads those artifacts through PJRT and
//!   executes the search-winning schedule, closing the loop from
//!   searched schedule to runnable kernel.
//!
//! ## Quickstart
//!
//! ```no_run
//! use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
//! use ecokernel::search::run_search;
//! use ecokernel::workload::suites;
//!
//! let cfg = SearchConfig { gpu: GpuArch::A100, mode: SearchMode::EnergyAware, ..Default::default() };
//! let outcome = run_search(suites::MM1, &cfg);
//! println!("best: {} ({:.3} ms, {:.2} mJ)",
//!          outcome.best.schedule,
//!          outcome.best.latency_s * 1e3,
//!          outcome.best.energy_j * 1e3);
//! ```

pub mod analysis;
pub mod baselines;
pub mod config;
pub mod costmodel;
pub mod features;
pub mod nvml;
pub mod schedule;
pub mod search;
pub mod sim;
pub mod store;
pub mod util;
pub mod workload;
// Wired in below as they land:
pub mod coordinator;
pub mod experiments;
pub mod fleet;
pub mod runtime;
/// Kernel-serving daemon (needs a Unix-ish socket runtime; unix-only).
#[cfg(unix)]
pub mod serve;
/// Mergeable histograms + hot-path stage tracing (pure data, portable).
pub mod telemetry;
