//! Memory-hierarchy traffic model: given a schedule and a GEMM view,
//! count the bytes that move at each level (DRAM, L2, shared, register
//! file) and the nvprof-style transaction counters of the paper's §8
//! case study (glb_ld, glb_st, shared_ld, shared_st).
//!
//! The accounting is the classic blocked-GEMM arithmetic:
//!
//! * operand `A` (`m x k`) is read once per **block column** — total
//!   element loads `ceil(n / bn) * m * k`;
//! * operand `B` (`k x n`) is read once per **block row** — total
//!   element loads `ceil(m / bm) * n * k`;
//! * larger block tiles => fewer global loads (more reuse per block) —
//!   the §8 energy lever;
//! * within a block, each thread reads its operand fragments from shared
//!   memory once per inner iteration — register tiling (`reg_m`,
//!   `reg_n`) divides the shared-load count by the fragment reuse.
//!
//! Re-reads are served by L2 when the re-read operand panel fits in L2
//! (tracked per operand); otherwise they spill to DRAM.

use crate::config::GpuSpec;
use crate::schedule::Schedule;
use crate::workload::GemmView;

/// Elements per global-memory transaction for a fully-coalesced FP32
/// warp access (32B sectors, nvprof convention).
pub const GLOBAL_COALESCE_ELEMS: f64 = 8.0;
/// Elements per shared-memory transaction with 128-bit vectorized
/// shared loads.
pub const SHARED_COALESCE_ELEMS: f64 = 4.0;

/// Byte and transaction counts for one kernel execution.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemoryTraffic {
    /// Bytes fetched from DRAM (compulsory + L2-miss re-reads + split-k
    /// partial-sum traffic).
    pub dram_bytes: f64,
    /// Bytes moving through L2 (all global traffic passes L2).
    pub l2_bytes: f64,
    /// Bytes moving through shared memory (both stores into the staging
    /// buffers and loads out of them).
    pub shared_bytes: f64,
    /// Bytes moving through the register file (operand reads +
    /// accumulator updates).
    pub reg_bytes: f64,
    /// nvprof-style transaction counters (per kernel run).
    pub glb_ld_txn: f64,
    pub glb_st_txn: f64,
    pub shared_ld_txn: f64,
    pub shared_st_txn: f64,
    /// Total global load *elements* (pre-coalescing), for diagnostics.
    pub glb_ld_elems: f64,
}

impl MemoryTraffic {
    /// Compute traffic for `sched` applied to `g` on `spec`.
    pub fn compute(sched: &Schedule, g: &GemmView, spec: &GpuSpec) -> MemoryTraffic {
        let bm = sched.block_m() as f64;
        let bn = sched.block_n() as f64;
        let (m, n, k) = (g.m as f64, g.n as f64, g.k as f64);
        let batch = g.batch as f64;
        let grid_m = (g.m as f64 / sched.block_m() as f64).ceil();
        let grid_n = (g.n as f64 / sched.block_n() as f64).ceil();

        // --- global loads (element granularity) -------------------------
        // A is re-read by every block column, B by every block row.
        // Padded tiles round the per-block panel up to the full tile.
        let loads_a = batch * grid_n * (grid_m * bm).max(m).min(2.0 * m) * k;
        let loads_b = batch * grid_m * (grid_n * bn).max(n).min(2.0 * n) * k;
        // Implicit im2col re-reads overlapping input windows; the overlap
        // factor k / (cin) ~ ksize^2 is already folded into g.k, but the
        // windows share rows, so A enjoys extra L2 locality instead of
        // extra DRAM traffic (handled via the L2-fit test below).
        let glb_ld_elems = loads_a + loads_b;

        // --- global stores ----------------------------------------------
        // split-k writes one partial tile per split, then a reduction
        // pass re-reads (split_k - 1) partials and writes the final tile.
        let sk = sched.split_k as f64;
        let out_elems = batch * m * n;
        let glb_st_elems = out_elems * sk + if sk > 1.0 { out_elems } else { 0.0 };
        let splitk_extra_ld = if sk > 1.0 { out_elems * sk } else { 0.0 };

        // --- L2 vs DRAM for re-reads -------------------------------------
        // An operand's re-reads hit L2 when the whole operand panel fits;
        // the first read is always compulsory DRAM traffic.
        let a_bytes_unique = batch * m * k * 4.0;
        let b_bytes_unique = batch * k * n * 4.0;
        let l2_cap = spec.l2_size as f64 * 0.8; // conservative usable frac
        let a_rereads = (loads_a * 4.0 - a_bytes_unique).max(0.0);
        let b_rereads = (loads_b * 4.0 - b_bytes_unique).max(0.0);
        let a_reread_dram = if a_bytes_unique <= l2_cap { 0.0 } else { a_rereads };
        let b_reread_dram = if b_bytes_unique <= l2_cap { 0.0 } else { b_rereads };

        let dram_bytes = a_bytes_unique
            + b_bytes_unique
            + a_reread_dram
            + b_reread_dram
            + glb_st_elems * 4.0
            + splitk_extra_ld * 4.0;
        let l2_bytes = (glb_ld_elems + glb_st_elems + splitk_extra_ld) * 4.0;

        // --- shared memory ------------------------------------------------
        // Stores into the staging buffers: every global-loaded element is
        // written to shared once. Loads out: each thread reads its
        // (reg_m + reg_n) fragment elements per k-iteration:
        //   total = batch * m*n*k * (1/reg_n + 1/reg_m)   [per-axis reuse]
        let (shared_st_elems, shared_ld_elems) = if sched.use_shared {
            let st = glb_ld_elems;
            let ld = batch
                * m
                * n
                * k
                * (1.0 / sched.reg_n as f64 + 1.0 / sched.reg_m.max(1) as f64);
            (st, ld)
        } else {
            (0.0, 0.0)
        };
        let shared_bytes = (shared_st_elems + shared_ld_elems) * 4.0;

        // --- register file -------------------------------------------------
        // Per MAC: 2 operand reads + 1 accumulator read-modify-write.
        let macs = batch * m * n * k;
        let reg_bytes = macs * 4.0 * 3.0;

        // --- transactions ----------------------------------------------------
        // Vectorized loads do not change sector counts when coalesced,
        // but scalar (v=1) accesses with small thread tiles coalesce
        // poorly on the B panel; model that as a granularity penalty.
        let glb_granule = if sched.vector_width >= 2 {
            GLOBAL_COALESCE_ELEMS
        } else {
            GLOBAL_COALESCE_ELEMS / 2.0
        };
        let st_granule = GLOBAL_COALESCE_ELEMS / 4.0 * sched.vector_width as f64;

        MemoryTraffic {
            dram_bytes,
            l2_bytes,
            shared_bytes,
            reg_bytes,
            glb_ld_txn: glb_ld_elems / glb_granule,
            glb_st_txn: glb_st_elems / st_granule,
            shared_ld_txn: shared_ld_elems / SHARED_COALESCE_ELEMS,
            shared_st_txn: shared_st_elems / SHARED_COALESCE_ELEMS,
            glb_ld_elems,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::workload::suites;

    fn sched(tm: usize, tn: usize, rm: usize, rn: usize, tk: usize) -> Schedule {
        Schedule {
            threads_m: tm,
            threads_n: tn,
            reg_m: rm,
            reg_n: rn,
            tile_k: tk,
            unroll_k: 4,
            vector_width: 4,
            split_k: 1,
            use_shared: true,
        }
    }

    #[test]
    fn bigger_tiles_mean_fewer_global_loads() {
        // The §8 case-study lever: K1 (64x64 tile) vs K2 (32x32 tile).
        let spec = GpuArch::A100.spec();
        let g = suites::MM1.gemm_view();
        let k1 = MemoryTraffic::compute(&sched(8, 8, 8, 8, 16), &g, &spec); // 64x64
        let k2 = MemoryTraffic::compute(&sched(8, 16, 4, 2, 16), &g, &spec); // 32x32
        assert!(k1.glb_ld_txn < k2.glb_ld_txn, "{} vs {}", k1.glb_ld_txn, k2.glb_ld_txn);
        assert!(k1.shared_ld_txn < k2.shared_ld_txn);
        assert!(k1.dram_bytes <= k2.dram_bytes);
    }

    #[test]
    fn compulsory_traffic_is_floor() {
        let spec = GpuArch::A100.spec();
        let g = suites::MM2.gemm_view();
        let t = MemoryTraffic::compute(&sched(16, 16, 8, 8, 32), &g, &spec);
        let compulsory = (g.batch * (g.m * g.k + g.k * g.n + g.m * g.n) * 4) as f64;
        assert!(t.dram_bytes >= compulsory * 0.999, "{} < {}", t.dram_bytes, compulsory);
    }

    #[test]
    fn split_k_adds_store_traffic() {
        let spec = GpuArch::A100.spec();
        let g = suites::MV1.gemm_view();
        let mut s = sched(1, 128, 1, 1, 32);
        s.vector_width = 4;
        let base = MemoryTraffic::compute(&s, &g, &spec);
        s.split_k = 8;
        let split = MemoryTraffic::compute(&s, &g, &spec);
        assert!(split.glb_st_txn > base.glb_st_txn);
        assert!(split.dram_bytes > base.dram_bytes);
    }

    #[test]
    fn register_tiling_divides_shared_loads() {
        let spec = GpuArch::A100.spec();
        let g = suites::MM1.gemm_view();
        let small_reg = MemoryTraffic::compute(&sched(16, 16, 2, 2, 16), &g, &spec);
        let big_reg = MemoryTraffic::compute(&sched(8, 8, 8, 8, 16), &g, &spec);
        assert!(big_reg.shared_ld_txn < small_reg.shared_ld_txn);
    }

    #[test]
    fn no_shared_means_no_shared_traffic() {
        let spec = GpuArch::A100.spec();
        let g = suites::MV3.gemm_view();
        let mut s = sched(1, 64, 1, 1, 16);
        s.use_shared = false;
        let t = MemoryTraffic::compute(&s, &g, &spec);
        assert_eq!(t.shared_bytes, 0.0);
        assert_eq!(t.shared_ld_txn, 0.0);
    }

    #[test]
    fn table5_ballpark_for_mm1() {
        // Paper Table 5, K1: grid 64, block 256, glb_ld 524288,
        // shared_ld 1572864 (MM 512^3, 64x64 block tiles). We check the
        // same order of magnitude, not exact calibration.
        let spec = GpuArch::A100.spec();
        let g = suites::MM1.gemm_view();
        let t = MemoryTraffic::compute(&sched(8, 8, 8, 8, 16), &g, &spec);
        assert!(
            (1e5..8e6).contains(&t.glb_ld_txn),
            "glb_ld_txn={} out of Table-5 ballpark",
            t.glb_ld_txn
        );
        assert!(
            (4e5..4e7).contains(&t.shared_ld_txn),
            "shared_ld_txn={} out of Table-5 ballpark",
            t.shared_ld_txn
        );
    }
}
