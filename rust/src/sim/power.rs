//! Power and energy model (§2.3 decomposition):
//!
//! `P_avg = P_constant + P_static(sm_efficiency, temp) + E_dynamic / latency`
//!
//! * **constant** — fans, peripheral circuits: independent of the kernel;
//! * **static** — leakage: a chip-wide floor plus a component scaling
//!   with the fraction of SMs kept busy (§8: idle SMs leak less), and a
//!   temperature multiplier (leakage grows with temperature — the reason
//!   the paper's NVML harness pre-heats, §4.4/§5.1);
//! * **dynamic** — energy per FLOP / int-op / byte moved at each memory
//!   level (AccelWattch-style event energies), paid once per kernel run
//!   regardless of how fast it runs.
//!
//! Because the dynamic energy is fixed per run, *faster kernels draw
//! higher average power* — the latency-power inverse correlation of
//! Fig. 3 falls out of this identity rather than being hard-coded.

use super::latency::LatencyBreakdown;
use super::memory::MemoryTraffic;
use crate::config::GpuSpec;
use crate::schedule::Schedule;
use crate::workload::GemmView;

/// Energy decomposition of one kernel run (joules).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub constant_j: f64,
    pub static_j: f64,
    pub compute_j: f64,
    pub int_j: f64,
    pub dram_j: f64,
    pub l2_j: f64,
    pub shared_j: f64,
    pub reg_j: f64,
    /// Memory-instruction issue energy (vectorization amortizes this).
    pub issue_j: f64,
    pub launch_j: f64,
}

impl EnergyBreakdown {
    /// Total energy (J).
    pub fn total_j(&self) -> f64 {
        self.constant_j
            + self.static_j
            + self.compute_j
            + self.int_j
            + self.dram_j
            + self.l2_j
            + self.shared_j
            + self.reg_j
            + self.issue_j
            + self.launch_j
    }

    /// Dynamic-only portion (J).
    pub fn dynamic_j(&self) -> f64 {
        self.compute_j + self.int_j + self.dram_j + self.l2_j + self.shared_j + self.reg_j
            + self.issue_j
            + self.launch_j
    }
}

/// Static power (W) at a given SM busy fraction and temperature.
pub fn static_power_w(spec: &GpuSpec, sm_efficiency: f64, temp_c: f64) -> f64 {
    let activity = spec.static_floor_frac + (1.0 - spec.static_floor_frac) * sm_efficiency;
    let thermal = 1.0 + spec.thermal_power_slope_per_c * (temp_c - spec.steady_temp_c);
    spec.static_power_full_w * activity * thermal.max(0.5)
}

/// Full power/energy evaluation of one kernel run at temperature `temp_c`.
pub fn energy(
    sched: &Schedule,
    g: &GemmView,
    traffic: &MemoryTraffic,
    lat: &LatencyBreakdown,
    spec: &GpuSpec,
    temp_c: f64,
) -> (EnergyBreakdown, f64) {
    let flops = 2.0 * g.macs() as f64;
    let iops = super::latency::int_ops(sched, g);
    let pj = 1e-12;

    // Memory instruction issues: each global load instruction covers
    // `vector_width` elements; shared/store instructions per transaction.
    let mem_issues = traffic.glb_ld_elems / sched.vector_width as f64
        + traffic.glb_st_txn
        + traffic.shared_ld_txn
        + traffic.shared_st_txn;
    let breakdown_dyn = EnergyBreakdown {
        constant_j: 0.0,
        static_j: 0.0,
        compute_j: flops * spec.energy_per_flop_pj * pj,
        int_j: iops * spec.energy_per_intop_pj * pj,
        dram_j: traffic.dram_bytes * spec.energy_per_dram_byte_pj * pj,
        l2_j: traffic.l2_bytes * spec.energy_per_l2_byte_pj * pj,
        shared_j: traffic.shared_bytes * spec.energy_per_shared_byte_pj * pj,
        reg_j: traffic.reg_bytes * spec.energy_per_reg_byte_pj * pj,
        issue_j: mem_issues * spec.energy_per_mem_issue_pj * pj,
        launch_j: spec.launch_energy_uj * 1e-6,
    };

    let p_static = static_power_w(spec, lat.occ.sm_efficiency, temp_c);
    let mut latency_s = lat.latency_s;
    let dynamic_j = breakdown_dyn.dynamic_j();

    // Power capping: if the run would exceed TDP, the GPU throttles
    // clocks — latency stretches so that average power == TDP. Dynamic
    // energy rises slightly at throttled voltage (simplified: constant).
    let p_avg_uncapped = spec.constant_power_w + p_static + dynamic_j / latency_s;
    if p_avg_uncapped > spec.tdp_w {
        let dyn_budget = spec.tdp_w - spec.constant_power_w - p_static;
        if dyn_budget > 1.0 {
            latency_s = dynamic_j / dyn_budget;
        }
    }

    // Voltage/frequency sensitivity: extremely fast, dense kernels run at
    // boost voltage; slow low-occupancy kernels let the driver drop to a
    // lower DVFS state, shaving dynamic energy. Modeled as a mild
    // monotone factor of power density.
    let density = (dynamic_j / latency_s) / spec.tdp_w;
    let dvfs = 0.92 + 0.16 * density.clamp(0.0, 1.0);
    let scale = dvfs;
    let breakdown = EnergyBreakdown {
        constant_j: spec.constant_power_w * latency_s,
        static_j: p_static * latency_s,
        compute_j: breakdown_dyn.compute_j * scale,
        int_j: breakdown_dyn.int_j * scale,
        dram_j: breakdown_dyn.dram_j * scale,
        l2_j: breakdown_dyn.l2_j * scale,
        shared_j: breakdown_dyn.shared_j * scale,
        reg_j: breakdown_dyn.reg_j * scale,
        issue_j: breakdown_dyn.issue_j * scale,
        launch_j: breakdown_dyn.launch_j,
    };

    (breakdown, latency_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::config::GpuArch;
    use crate::sim::latency::latency;
    use crate::workload::suites;

    fn eval(s: &Schedule) -> (EnergyBreakdown, f64, f64) {
        let spec = GpuArch::A100.spec();
        let g = suites::MM1.gemm_view();
        let t = MemoryTraffic::compute(s, &g, &spec);
        let lb = latency(s, &g, &t, &spec);
        let (e, lat_s) = energy(s, &g, &t, &lb, &spec, spec.steady_temp_c);
        (e, lat_s, e.total_j() / lat_s)
    }

    fn sched(tm: usize, tn: usize, rm: usize, rn: usize) -> Schedule {
        Schedule {
            threads_m: tm,
            threads_n: tn,
            reg_m: rm,
            reg_n: rn,
            tile_k: 16,
            unroll_k: 4,
            vector_width: 4,
            split_k: 1,
            use_shared: true,
        }
    }

    #[test]
    fn mm1_energy_in_paper_ballpark() {
        // Paper Table 2: MM1 energy 6.5-8.3 mJ, power 184-239 W.
        let (e, _lat, p) = eval(&sched(8, 8, 8, 8));
        let mj = e.total_j() * 1e3;
        assert!((1.0..40.0).contains(&mj), "MM1 energy {mj} mJ");
        assert!((80.0..420.0).contains(&p), "MM1 power {p} W");
    }

    #[test]
    fn static_power_scales_with_sm_efficiency() {
        let spec = GpuArch::A100.spec();
        let lo = static_power_w(&spec, 0.5, spec.steady_temp_c);
        let hi = static_power_w(&spec, 1.0, spec.steady_temp_c);
        assert!(hi > lo);
        let floor = static_power_w(&spec, 0.0, spec.steady_temp_c);
        assert!(floor > 0.2 * spec.static_power_full_w, "leakage floor exists");
    }

    #[test]
    fn temperature_raises_static_power() {
        let spec = GpuArch::A100.spec();
        let cold = static_power_w(&spec, 0.8, spec.idle_temp_c);
        let hot = static_power_w(&spec, 0.8, spec.steady_temp_c + 15.0);
        assert!(hot > cold);
    }

    #[test]
    fn constant_plus_static_is_large_fraction() {
        // §2.3: constant + static are 40-50% of typical GPU power. Our
        // moderately-utilized MM kernel should show a hefty non-dynamic
        // share.
        let (e, _lat, _p) = eval(&sched(8, 16, 4, 2));
        let frac = (e.constant_j + e.static_j) / e.total_j();
        assert!((0.25..0.9).contains(&frac), "non-dynamic frac {frac}");
    }

    #[test]
    fn average_power_below_tdp() {
        use crate::schedule::space::ScheduleSpace;
        
        let spec = GpuArch::A100.spec();
        let mut rng = Rng::seed_from_u64(11);
        for (_, w) in suites::all_named() {
            let g = w.gemm_view();
            let space = ScheduleSpace::new(w, &spec);
            for s in space.sample_n(&mut rng, 16) {
                let t = MemoryTraffic::compute(&s, &g, &spec);
                let lb = latency(&s, &g, &t, &spec);
                let (e, lat_s) = energy(&s, &g, &t, &lb, &spec, spec.steady_temp_c);
                let p = e.total_j() / lat_s;
                assert!(p <= spec.tdp_w * 1.02, "power {p} exceeds TDP");
                assert!(p >= spec.constant_power_w * 0.9, "power {p} below constant floor");
            }
        }
    }
}
