//! The GPU simulator substrate: deterministic, closed-form evaluation of
//! (workload, schedule) pairs on a parameterized architecture.
//!
//! This replaces the paper's physical A100 / RTX 4090 / P100 testbed
//! (see DESIGN.md §3 for the substitution argument). [`evaluate`] is the
//! noise-free *ground truth* at steady temperature; [`crate::nvml`]
//! wraps it with sampling noise, thermal drift, and measurement time
//! cost, exactly as NVML-based measurement wraps physical truth.

pub mod latency;
pub mod memory;
pub mod power;
pub mod profile;
pub mod temperature;

pub use latency::{occupancy, LatencyBreakdown, Occupancy};
pub use memory::MemoryTraffic;
pub use power::{static_power_w, EnergyBreakdown};
pub use profile::KernelProfile;
pub use temperature::ThermalState;

use crate::config::GpuSpec;
use crate::schedule::{Candidate, Schedule};
use crate::workload::GemmView;

/// Complete steady-state evaluation of one kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Evaluation {
    /// Latency of one kernel run, seconds.
    pub latency_s: f64,
    /// Energy of one kernel run, joules.
    pub energy_j: f64,
    /// Average power during the run, watts.
    pub avg_power_w: f64,
    /// Time-averaged SM busy fraction.
    pub sm_efficiency: f64,
    /// Resident-thread occupancy fraction.
    pub occupancy: f64,
    /// Scheduling waves.
    pub waves: usize,
    /// Achieved fraction of peak FLOPs.
    pub compute_efficiency: f64,
    /// Energy decomposition.
    pub breakdown: EnergyBreakdown,
    /// nvprof-style counters.
    pub profile: KernelProfile,
}

/// Evaluate `sched` on `g` at steady measurement temperature.
pub fn evaluate(g: &GemmView, sched: &Schedule, spec: &GpuSpec) -> Evaluation {
    evaluate_at(g, sched, spec, spec.steady_temp_c)
}

/// Evaluate at an explicit die temperature (used by the NVML harness).
pub fn evaluate_at(g: &GemmView, sched: &Schedule, spec: &GpuSpec, temp_c: f64) -> Evaluation {
    let traffic = MemoryTraffic::compute(sched, g, spec);
    let lat = latency::latency(sched, g, &traffic, spec);
    let (breakdown, latency_s) = power::energy(sched, g, &traffic, &lat, spec, temp_c);
    let energy_j = breakdown.total_j();
    let ev = Evaluation {
        latency_s,
        energy_j,
        avg_power_w: energy_j / latency_s,
        sm_efficiency: lat.occ.sm_efficiency,
        occupancy: lat.occ.occupancy,
        waves: lat.occ.waves,
        compute_efficiency: lat.compute_efficiency,
        breakdown,
        profile: KernelProfile {
            grid: 0,
            block: 0,
            sm_efficiency_pct: 0.0,
            glb_ld: 0,
            glb_st: 0,
            shared_ld: 0,
            shared_st: 0,
            occupancy: 0.0,
            waves: 0,
            flop_efficiency: 0.0,
            dram_bytes: 0,
        },
    };
    let profile = KernelProfile::new(sched, g, &traffic, &ev);
    Evaluation { profile, ..ev }
}

/// Convenience: evaluate a bound candidate.
pub fn evaluate_candidate(c: &Candidate, spec: &GpuSpec) -> Evaluation {
    evaluate(&c.gemm(), &c.schedule, spec)
}

/// Latency-only fast path (skips the energy model) — the inner loop of
/// `LatencyEvaAndPick` calls this for every genetic child, so it is a
/// perf-critical hot path (see EXPERIMENTS.md §Perf).
pub fn evaluate_latency(g: &GemmView, sched: &Schedule, spec: &GpuSpec) -> f64 {
    let traffic = MemoryTraffic::compute(sched, g, spec);
    let lat = latency::latency(sched, g, &traffic, spec);
    // Apply the same TDP throttle the full path applies so latency-only
    // and full evaluations agree.
    let (_, latency_s) = power::energy(sched, g, &traffic, &lat, spec, spec.steady_temp_c);
    latency_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::config::GpuArch;
    use crate::schedule::space::ScheduleSpace;
    use crate::workload::suites;
    
    

    #[test]
    fn evaluation_identity_energy_eq_power_times_latency() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM2, &spec);
        let mut rng = Rng::seed_from_u64(3);
        for s in space.sample_n(&mut rng, 32) {
            let ev = evaluate(&suites::MM2.gemm_view(), &s, &spec);
            let recon = ev.avg_power_w * ev.latency_s;
            assert!((recon - ev.energy_j).abs() / ev.energy_j < 1e-9);
            assert!((ev.breakdown.total_j() - ev.energy_j).abs() < 1e-12);
        }
    }

    #[test]
    fn latency_fast_path_matches_full_eval() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::CONV2, &spec);
        let mut rng = Rng::seed_from_u64(4);
        let g = suites::CONV2.gemm_view();
        for s in space.sample_n(&mut rng, 16) {
            let full = evaluate(&g, &s, &spec).latency_s;
            let fast = evaluate_latency(&g, &s, &spec);
            assert!((full - fast).abs() / full < 1e-9);
        }
    }

    #[test]
    fn latency_power_inverse_correlation_fig3() {
        // Fig. 3: across MM(1024^3) schedules, higher latency correlates
        // with lower average power. Pearson r must be clearly negative.
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM2, &spec);
        let mut rng = Rng::seed_from_u64(9);
        let g = suites::MM2.gemm_view();
        let evs: Vec<Evaluation> =
            space.sample_n(&mut rng, 300).iter().map(|s| evaluate(&g, s, &spec)).collect();
        let xs: Vec<f64> = evs.iter().map(|e| e.latency_s).collect();
        let ys: Vec<f64> = evs.iter().map(|e| e.avg_power_w).collect();
        let r = pearson(&xs, &ys);
        assert!(r < -0.3, "latency-power correlation r={r} not inverse");
    }

    #[test]
    fn energy_not_monotone_in_latency() {
        // §4.1: kernels with similar latency can differ notably in
        // energy. Find two schedules within 10% latency whose energies
        // differ by > 10%.
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(21);
        let g = suites::MM1.gemm_view();
        let evs: Vec<Evaluation> =
            space.sample_n(&mut rng, 400).iter().map(|s| evaluate(&g, s, &spec)).collect();
        let mut found = false;
        'outer: for a in &evs {
            for b in &evs {
                let dl = (a.latency_s - b.latency_s).abs() / a.latency_s;
                let de = (a.energy_j - b.energy_j).abs() / a.energy_j;
                if dl < 0.10 && de > 0.10 {
                    found = true;
                    break 'outer;
                }
            }
        }
        assert!(found, "no similar-latency, different-energy pair found");
    }

    #[test]
    fn temperature_increases_energy() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let s = space.fallback();
        let g = suites::MM1.gemm_view();
        let cold = evaluate_at(&g, &s, &spec, spec.idle_temp_c);
        let hot = evaluate_at(&g, &s, &spec, spec.steady_temp_c + 10.0);
        assert!(hot.energy_j > cold.energy_j);
    }

    pub(crate) fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
        let n = xs.len() as f64;
        let mx = xs.iter().sum::<f64>() / n;
        let my = ys.iter().sum::<f64>() / n;
        let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let vx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
        let vy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
        cov / (vx.sqrt() * vy.sqrt()).max(1e-30)
    }
}
