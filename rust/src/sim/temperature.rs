//! Thermal state of the simulated GPU.
//!
//! §5.1: "Temperature variations significantly affect transistor
//! behavior, leading to notable differences in GPU energy consumption
//! even when executing the same workload. ... each kernel measurement is
//! preceded by a warm-up period of several seconds to stabilize the GPU
//! at a consistent temperature."
//!
//! We model first-order exponential thermal dynamics: under load the die
//! approaches a power-dependent steady temperature; idle, it decays
//! toward ambient. [`crate::nvml`] advances this state as measurements
//! consume (simulated) time, so skipping the warm-up yields biased,
//! drifting energy readings — exactly the failure mode the paper's
//! harness avoids.

use crate::config::GpuSpec;

/// First-order thermal model of one GPU die.
#[derive(Debug, Clone)]
pub struct ThermalState {
    /// Current die temperature, C.
    pub temp_c: f64,
    /// Ambient/idle temperature, C.
    idle_c: f64,
    /// Steady temperature at full sustained load, C.
    steady_c: f64,
    /// Heating time constant, s.
    tau_heat_s: f64,
    /// Cooling time constant, s.
    tau_cool_s: f64,
}

impl ThermalState {
    /// Cold GPU for `spec`.
    pub fn cold(spec: &GpuSpec) -> ThermalState {
        ThermalState {
            temp_c: spec.idle_temp_c,
            idle_c: spec.idle_temp_c,
            steady_c: spec.steady_temp_c,
            tau_heat_s: 20.0,
            tau_cool_s: 45.0,
        }
    }

    /// GPU already warmed to the measurement steady state.
    pub fn warmed(spec: &GpuSpec) -> ThermalState {
        let mut t = Self::cold(spec);
        t.temp_c = spec.steady_temp_c;
        t
    }

    /// Advance `dt_s` seconds under load at `power_frac` of TDP.
    pub fn run_load(&mut self, dt_s: f64, power_frac: f64) {
        // Load target scales mildly with drawn power around the steady point.
        let target = self.idle_c
            + (self.steady_c - self.idle_c) * (0.55 + 0.6 * power_frac.clamp(0.0, 1.2));
        let a = 1.0 - (-dt_s / self.tau_heat_s).exp();
        self.temp_c += (target - self.temp_c) * a;
    }

    /// Advance `dt_s` seconds idle (cooling).
    pub fn run_idle(&mut self, dt_s: f64) {
        let a = 1.0 - (-dt_s / self.tau_cool_s).exp();
        self.temp_c += (self.idle_c - self.temp_c) * a;
    }

    /// Whether the die is within `tol_c` of the measurement steady state.
    pub fn is_steady(&self, tol_c: f64) -> bool {
        (self.temp_c - self.steady_c).abs() <= tol_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;

    #[test]
    fn warms_up_under_load() {
        let spec = GpuArch::A100.spec();
        let mut t = ThermalState::cold(&spec);
        assert!(!t.is_steady(2.0));
        for _ in 0..30 {
            t.run_load(1.0, 0.8);
        }
        assert!(t.temp_c > spec.idle_temp_c + 15.0);
    }

    #[test]
    fn cools_when_idle() {
        let spec = GpuArch::A100.spec();
        let mut t = ThermalState::warmed(&spec);
        let before = t.temp_c;
        t.run_idle(60.0);
        assert!(t.temp_c < before);
        assert!(t.temp_c >= spec.idle_temp_c - 1e-9);
    }

    #[test]
    fn steady_state_is_stable() {
        let spec = GpuArch::A100.spec();
        let mut t = ThermalState::warmed(&spec);
        t.run_load(5.0, 0.75);
        assert!(t.is_steady(6.0), "temp {} drifted too far", t.temp_c);
    }
}
