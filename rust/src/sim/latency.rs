//! Analytical latency model: occupancy, wave quantization, ILP, and a
//! compute/memory roofline.
//!
//! The model composes:
//!
//! 1. **Occupancy** — resident blocks per SM limited by threads, shared
//!    memory, and registers; low occupancy cannot hide pipeline and
//!    memory latency, discounting achievable compute throughput.
//! 2. **Wave quantization** — `ceil(grid / slots)` waves; the tail wave
//!    leaves SMs idle (this also drives `sm_efficiency`, see
//!    [`super::profile`]).
//! 3. **ILP efficiency** — register tiles amortize shared loads over
//!    FMAs; unrolling amortizes loop/addressing overhead.
//! 4. **Roofline** — latency is the max of compute time and memory time
//!    (with a mild overlap penalty), plus launch overhead.

use super::memory::MemoryTraffic;
use crate::config::GpuSpec;
use crate::schedule::Schedule;
use crate::workload::GemmView;

/// Occupancy and wave geometry for a launch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Occupancy {
    /// Resource-capacity blocks per SM (governs wave quantization).
    pub blocks_per_sm: usize,
    /// Blocks actually resident per SM given the grid size.
    pub resident_blocks: usize,
    /// Fraction of max resident threads used.
    pub occupancy: f64,
    /// SMs with at least one block at launch.
    pub active_sms: usize,
    /// Number of scheduling waves.
    pub waves: usize,
    /// Efficiency of the wave schedule (1.0 = all slots busy all waves).
    pub tail_efficiency: f64,
    /// Time-averaged fraction of SMs busy (nvprof `sm_efficiency`).
    pub sm_efficiency: f64,
}

/// Compute occupancy/wave geometry for `sched` on `spec` with `grid` blocks.
pub fn occupancy(sched: &Schedule, grid: usize, spec: &GpuSpec) -> Occupancy {
    let tpb = sched.threads_per_block();
    let by_threads = spec.max_threads_per_sm / tpb.max(1);
    let by_blocks = spec.max_blocks_per_sm;
    let shared = sched.shared_bytes_per_block();
    let by_shared =
        if shared == 0 { usize::MAX } else { spec.shared_mem_per_sm / shared };
    let regs = sched.regs_per_thread() * tpb;
    let by_regs = if regs == 0 { usize::MAX } else { spec.regs_per_sm / regs };
    let blocks_per_sm = by_threads.min(by_blocks).min(by_shared).min(by_regs).max(1);

    // *Achieved* occupancy uses the blocks actually resident per SM —
    // a small grid cannot stack blocks up to capacity. (Capacity still
    // governs wave quantization below.)
    let resident_blocks = blocks_per_sm.min(grid.div_ceil(spec.num_sms).max(1));
    let occupancy_frac =
        (resident_blocks * tpb) as f64 / spec.max_threads_per_sm as f64;

    let slots = spec.num_sms * blocks_per_sm;
    let waves = grid.div_ceil(slots).max(1);
    let active_sms = grid.min(spec.num_sms);

    // Tail efficiency: fraction of block-slots over all waves that do work.
    let used_slots = grid as f64;
    let total_slots = (waves * slots.min(grid.max(1)).max(1)) as f64;
    let tail_efficiency = (used_slots / total_slots).min(1.0);

    // sm_efficiency: time-averaged fraction of SMs with >= 1 resident
    // block. The hardware scheduler spreads blocks round-robin across
    // SMs before stacking them, so a tail of `t` blocks keeps
    // min(t, num_sms) SMs busy.
    let full_waves = grid / slots;
    let tail_blocks = grid % slots;
    let tail_sms = tail_blocks.min(spec.num_sms);
    let busy_sm_time = full_waves * spec.num_sms + tail_sms;
    let total_sm_time = waves * spec.num_sms;
    // A small duty-cycle discount: even a busy SM has drain/ramp gaps.
    let duty = 0.97;
    let sm_efficiency =
        (busy_sm_time as f64 / total_sm_time as f64 * duty).clamp(0.0, 1.0);

    Occupancy {
        blocks_per_sm,
        resident_blocks,
        occupancy: occupancy_frac.min(1.0),
        active_sms,
        waves,
        tail_efficiency,
        sm_efficiency,
    }
}

/// Integer (addressing/loop) operation estimate for a schedule.
///
/// Deeper unrolls and larger register tiles amortize per-iteration index
/// arithmetic; implicit im2col adds per-element window arithmetic.
pub fn int_ops(sched: &Schedule, g: &GemmView) -> f64 {
    let macs = g.macs() as f64;
    let per_mac_loop = 1.2 / sched.unroll_k as f64;
    let per_mac_addr = 2.0 / (sched.reg_m * sched.reg_n) as f64;
    let im2col = if g.im2col { 0.35 } else { 0.0 };
    let per_block = (sched.threads_per_block() * 40) as f64;
    macs * (per_mac_loop + per_mac_addr + im2col)
        + sched.grid(g) as f64 * per_block
}

/// Latency estimate plus the intermediate terms (exposed for features
/// and for the Fig. 3 power analysis).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyBreakdown {
    /// Seconds: final latency of one kernel run.
    pub latency_s: f64,
    pub compute_s: f64,
    pub dram_s: f64,
    pub l2_s: f64,
    pub shared_s: f64,
    /// Achieved fraction of per-SM peak FLOPs.
    pub compute_efficiency: f64,
    pub occ: Occupancy,
}

/// The latency model.
pub fn latency(
    sched: &Schedule,
    g: &GemmView,
    traffic: &MemoryTraffic,
    spec: &GpuSpec,
) -> LatencyBreakdown {
    let grid = sched.grid(g);
    let occ = occupancy(sched, grid, spec);
    let flops = 2.0 * g.macs() as f64;

    // --- compute efficiency --------------------------------------------
    // ILP: each inner iteration issues reg_m*reg_n FMAs against
    // (reg_m + reg_n) shared-load fragments plus loop overhead.
    let rm = sched.reg_m as f64;
    let rn = sched.reg_n as f64;
    let fma = rm * rn;
    let ilp_eff = fma / (fma + 0.55 * (rm + rn) + 1.6 / sched.unroll_k as f64);
    // Latency hiding: an SM has 4 scheduler partitions (needs >= 4
    // resident warps to issue on all of them), and the FMA pipeline
    // needs ~64 independent in-flight ops per SM — supplied either by
    // warp parallelism (occupancy) or by per-thread accumulator ILP
    // (register tiles). This is the §8 mechanism letting a
    // low-occupancy, big-register-tile block match a high-occupancy
    // small-tile one.
    let resident_warps =
        (occ.resident_blocks * sched.threads_per_block()) as f64 / 32.0;
    let partition_eff = (resident_warps / 4.0).min(1.0);
    let inflight = resident_warps * fma;
    let hide_eff = (inflight / 64.0).min(1.0);
    // Shared-memory staging needs a block-wide barrier every k-step;
    // blocks with few warps cannot hide the barrier + staging latency
    // (the reason CUDA kernels want >= 128-256 threads per block).
    let barrier_eff = if sched.use_shared {
        let warps_per_block = (sched.threads_per_block() as f64 / 32.0).max(1.0);
        warps_per_block / (warps_per_block + 2.0)
    } else {
        1.0
    };
    let occ_eff = partition_eff * hide_eff * barrier_eff;
    // Integer overhead competes for issue slots.
    let iops = int_ops(sched, g);
    let int_dilution = flops / (flops + 0.5 * iops);
    let compute_efficiency =
        (ilp_eff * occ_eff * int_dilution).clamp(0.02, 0.98);

    let peak = spec.peak_gflops_per_sm() * 1e9 * occ.active_sms as f64;
    let compute_s = flops / (peak * compute_efficiency * occ.tail_efficiency);

    // --- memory time -----------------------------------------------------
    // Vectorized global loads improve achieved DRAM bandwidth.
    let vec_bw = match sched.vector_width {
        4 => 1.0,
        2 => 0.92,
        _ => 0.78,
    };
    let dram_s = traffic.dram_bytes / (spec.dram_bw_gbs * 1e9 * vec_bw);
    let l2_s = traffic.l2_bytes / (spec.l2_bw_gbs * 1e9);
    let shared_s = traffic.shared_bytes
        / (spec.shared_bw_per_sm_gbs * 1e9 * occ.active_sms.max(1) as f64);
    let mem_s: f64 = dram_s.max(l2_s) + shared_s;

    // --- roofline compose --------------------------------------------------
    // max() with a mild non-overlap term: real kernels never overlap
    // perfectly.
    let overlap_penalty = 0.12 * compute_s.min(mem_s);
    let latency_s =
        compute_s.max(mem_s) + overlap_penalty + spec.launch_latency_us * 1e-6;

    LatencyBreakdown {
        latency_s,
        compute_s,
        dram_s,
        l2_s,
        shared_s,
        compute_efficiency,
        occ,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::config::GpuArch;
    use crate::workload::suites;

    fn sched(tm: usize, tn: usize, rm: usize, rn: usize, tk: usize) -> Schedule {
        Schedule {
            threads_m: tm,
            threads_n: tn,
            reg_m: rm,
            reg_n: rn,
            tile_k: tk,
            unroll_k: 4,
            vector_width: 4,
            split_k: 1,
            use_shared: true,
        }
    }

    fn eval(s: &Schedule, w: crate::workload::Workload) -> LatencyBreakdown {
        let spec = GpuArch::A100.spec();
        let g = w.gemm_view();
        let t = MemoryTraffic::compute(s, &g, &spec);
        latency(s, &g, &t, &spec)
    }

    #[test]
    fn mm1_latency_in_paper_ballpark() {
        // Paper Table 2: MM1 latency ~0.035 ms on A100. A decent tiled
        // schedule should land within ~3x of that.
        let lb = eval(&sched(8, 8, 8, 8, 16), suites::MM1);
        let ms = lb.latency_s * 1e3;
        assert!((0.01..0.15).contains(&ms), "MM1 latency {ms} ms");
    }

    #[test]
    fn mv1_latency_is_bandwidth_dominated() {
        // MV1 moves ~2.4 GB of weights; at ~2 TB/s that's >= 1.1 ms.
        // Use a sensible streaming schedule (no shared staging, wide
        // vector loads, enough occupancy to hide memory latency).
        let mut s = sched(1, 128, 1, 4, 32);
        s.threads_m = 1;
        s.reg_m = 1;
        s.use_shared = false;
        let lb = eval(&s, suites::MV1);
        let ms = lb.latency_s * 1e3;
        assert!(ms > 0.9, "MV1 latency {ms} ms too fast for DRAM");
        assert!(lb.dram_s > lb.compute_s, "MV must be memory bound");
    }

    #[test]
    fn occupancy_limits_apply() {
        let spec = GpuArch::A100.spec();
        // Huge shared usage limits blocks/SM.
        let fat = sched(16, 16, 8, 8, 64); // 128x128 tile, big panels
        let occ_fat = occupancy(&fat, 1000, &spec);
        let thin = sched(8, 8, 2, 2, 8);
        let occ_thin = occupancy(&thin, 1000, &spec);
        assert!(occ_fat.blocks_per_sm <= occ_thin.blocks_per_sm);
        assert!(occ_fat.occupancy <= 1.0 && occ_thin.occupancy <= 1.0);
    }

    #[test]
    fn sm_efficiency_matches_case_study_shape() {
        // §8: grid 64 on 108 SMs -> sm_eff ~0.56-0.60; grid 256 -> ~0.8.
        let spec = GpuArch::A100.spec();
        let mut k1 = sched(8, 8, 8, 8, 16);
        k1.reg_m = 8; // 64 x 64 tile, grid 64 for 512^2
        let o1 = occupancy(&k1, 64, &spec);
        assert!((0.50..0.65).contains(&o1.sm_efficiency), "{}", o1.sm_efficiency);

        let o2 = occupancy(&sched(8, 16, 4, 2, 16), 256, &spec);
        assert!(
            o2.sm_efficiency > o1.sm_efficiency,
            "{} vs {}",
            o2.sm_efficiency,
            o1.sm_efficiency
        );
    }

    #[test]
    fn wave_tail_hurts() {
        let spec = GpuArch::A100.spec();
        let s = sched(8, 16, 4, 4, 16);
        // Fill every block slot exactly, then overflow by one block.
        let slots = occupancy(&s, 1, &spec).blocks_per_sm * spec.num_sms;
        let full = occupancy(&s, slots, &spec);
        let tail = occupancy(&s, slots + 1, &spec);
        assert!(tail.tail_efficiency < full.tail_efficiency);
        assert_eq!(tail.waves, full.waves + 1);
        assert!(tail.sm_efficiency < full.sm_efficiency);
    }

    #[test]
    fn unroll_reduces_int_ops() {
        let g = suites::MM1.gemm_view();
        let mut a = sched(8, 8, 4, 4, 16);
        a.unroll_k = 1;
        let mut b = a;
        b.unroll_k = 8;
        assert!(int_ops(&b, &g) < int_ops(&a, &g));
    }

    #[test]
    fn latency_is_positive_and_finite_for_random_schedules() {
        use crate::schedule::space::ScheduleSpace;
        
        let spec = GpuArch::A100.spec();
        let mut rng = Rng::seed_from_u64(5);
        for (_, w) in suites::all_named() {
            let space = ScheduleSpace::new(w, &spec);
            let g = w.gemm_view();
            for s in space.sample_n(&mut rng, 32) {
                let t = MemoryTraffic::compute(&s, &g, &spec);
                let lb = latency(&s, &g, &t, &spec);
                assert!(lb.latency_s.is_finite() && lb.latency_s > 0.0);
                assert!(lb.compute_efficiency > 0.0 && lb.compute_efficiency < 1.0);
            }
        }
    }
}
