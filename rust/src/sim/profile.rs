//! nvprof-style kernel profile: the counters the paper's §8 case study
//! reports (Table 5) plus the roofline diagnostics used by the perf
//! pass.

use super::memory::MemoryTraffic;
use super::Evaluation;
use crate::schedule::Schedule;
use crate::workload::GemmView;

/// The Table-5 counter set for one kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelProfile {
    /// Number of thread blocks launched.
    pub grid: usize,
    /// Threads per block.
    pub block: usize,
    /// Time-averaged fraction of SMs busy (percent, like nvprof).
    pub sm_efficiency_pct: f64,
    /// Global load transactions.
    pub glb_ld: u64,
    /// Global store transactions.
    pub glb_st: u64,
    /// Shared load transactions.
    pub shared_ld: u64,
    /// Shared store transactions.
    pub shared_st: u64,
    /// Occupancy (resident-thread fraction).
    pub occupancy: f64,
    /// Scheduling waves.
    pub waves: usize,
    /// Achieved fraction of peak FLOPs.
    pub flop_efficiency: f64,
    /// DRAM bytes moved.
    pub dram_bytes: u64,
}

impl KernelProfile {
    pub fn new(sched: &Schedule, g: &GemmView, traffic: &MemoryTraffic, ev: &Evaluation) -> Self {
        KernelProfile {
            grid: sched.grid(g),
            block: sched.threads_per_block(),
            sm_efficiency_pct: ev.sm_efficiency * 100.0,
            glb_ld: traffic.glb_ld_txn as u64,
            glb_st: traffic.glb_st_txn as u64,
            shared_ld: traffic.shared_ld_txn as u64,
            shared_st: traffic.shared_st_txn as u64,
            occupancy: ev.occupancy,
            waves: ev.waves,
            flop_efficiency: ev.compute_efficiency,
            dram_bytes: traffic.dram_bytes as u64,
        }
    }

    /// A Table-5-style single row: `grid block sm_eff glb_ld glb_st shared_ld shared_st`.
    pub fn table5_row(&self) -> String {
        format!(
            "{:>6} {:>6} {:>12.2}% {:>12} {:>10} {:>12} {:>10}",
            self.grid,
            self.block,
            self.sm_efficiency_pct,
            self.glb_ld,
            self.glb_st,
            self.shared_ld,
            self.shared_st
        )
    }
}

#[cfg(test)]
mod tests {
    use crate::config::GpuArch;
    use crate::schedule::Schedule;
    use crate::sim::evaluate;
    use crate::workload::suites;

    #[test]
    fn profile_reports_launch_geometry() {
        let spec = GpuArch::A100.spec();
        let s = Schedule {
            threads_m: 8,
            threads_n: 32,
            reg_m: 8,
            reg_n: 2,
            tile_k: 16,
            unroll_k: 4,
            vector_width: 4,
            split_k: 1,
            use_shared: true,
        };
        let ev = evaluate(&suites::MM1.gemm_view(), &s, &spec);
        let p = ev.profile;
        // 64x64 block tile over 512x512 -> grid 64, block 256.
        assert_eq!(p.grid, 64);
        assert_eq!(p.block, 256);
        assert!(p.sm_efficiency_pct > 30.0 && p.sm_efficiency_pct < 100.0);
        assert!(p.glb_ld > 0 && p.shared_ld > 0);
        let row = p.table5_row();
        assert!(row.contains("64") && row.contains("256"));
    }
}
