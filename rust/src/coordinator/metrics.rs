//! Aggregate metrics across a batch of searches (suite-level telemetry
//! printed at the end of experiments and logged as a summary event).

use crate::nvml::MeasurementClock;
use crate::search::SearchOutcome;

/// Suite-level aggregate counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SuiteMetrics {
    pub n_searches: usize,
    pub total_energy_measurements: usize,
    pub total_latency_timings: usize,
    pub total_sim_time_s: f64,
    pub total_warmup_s: f64,
    pub total_model_time_s: f64,
    pub total_rounds: usize,
    /// Jobs served from the tuning store without dispatching a search.
    pub n_cache_hits: usize,
}

impl SuiteMetrics {
    pub fn absorb(&mut self, out: &SearchOutcome) {
        self.n_searches += 1;
        self.total_energy_measurements += out.clock.n_energy_measurements;
        self.total_latency_timings += out.clock.n_latency_timings;
        self.total_sim_time_s += out.clock.total_s;
        self.total_warmup_s += out.clock.warmup_s;
        self.total_model_time_s += out.clock.model_predict_s + out.clock.model_train_s;
        self.total_rounds += out.rounds.len();
    }

    pub fn absorb_clock(&mut self, clock: &MeasurementClock) {
        self.total_energy_measurements += clock.n_energy_measurements;
        self.total_latency_timings += clock.n_latency_timings;
        self.total_sim_time_s += clock.total_s;
    }

    /// Mean energy measurements per search round (the quantity the
    /// dynamic-k strategy reduces).
    pub fn measurements_per_round(&self) -> f64 {
        if self.total_rounds == 0 {
            return 0.0;
        }
        self.total_energy_measurements as f64 / self.total_rounds as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "searches={} cache_hits={} rounds={} energy_meas={} lat_timings={} sim_time={:.1}s (warmup {:.1}s, model {:.2}s)",
            self.n_searches,
            self.n_cache_hits,
            self.total_rounds,
            self.total_energy_measurements,
            self.total_latency_timings,
            self.total_sim_time_s,
            self.total_warmup_s,
            self.total_model_time_s,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuArch, SearchConfig, SearchMode};
    use crate::search::run_search;
    use crate::workload::suites;

    #[test]
    fn metrics_absorb_outcomes() {
        let cfg = SearchConfig {
            gpu: GpuArch::A100,
            mode: SearchMode::EnergyAware,
            population: 32,
            m_latency_keep: 8,
            rounds: 3,
            patience: 0,
            ..Default::default()
        };
        let out = run_search(suites::MM1, &cfg);
        let mut m = SuiteMetrics::default();
        m.absorb(&out);
        assert_eq!(m.n_searches, 1);
        assert!(m.total_energy_measurements >= 8);
        assert!(m.total_sim_time_s > 0.0);
        assert!(m.measurements_per_round() > 0.0);
        assert!(m.summary().contains("searches=1"));
    }
}
