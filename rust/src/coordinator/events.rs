//! JSONL event log: one line per search/coordination event, consumable
//! by external tooling (and by the tests, which parse it back).

use crate::util::Json;
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;

/// A thread-safe JSONL sink.
pub struct EventLog {
    sink: Mutex<Box<dyn Write + Send>>,
}

impl EventLog {
    /// Log to a file (created/truncated).
    pub fn to_file(path: &Path) -> anyhow::Result<EventLog> {
        let f = std::fs::File::create(path)?;
        Ok(EventLog { sink: Mutex::new(Box::new(std::io::BufWriter::new(f))) })
    }

    /// Log to an in-memory buffer (testing) — retrieve with `drain_vec`.
    pub fn to_vec() -> (EventLog, std::sync::Arc<Mutex<Vec<u8>>>) {
        let buf = std::sync::Arc::new(Mutex::new(Vec::new()));
        let writer = SharedVecWriter(buf.clone());
        (EventLog { sink: Mutex::new(Box::new(writer)) }, buf)
    }

    /// Append one event (object with at least "event" and "ts" fields).
    pub fn emit(&self, event: &str, fields: Vec<(&str, Json)>) {
        let mut all = vec![
            ("event", Json::str(event)),
            ("ts_unix", Json::num(unix_now())),
        ];
        all.extend(fields);
        let line = Json::obj(all).to_string();
        let mut sink = self.sink.lock().expect("event sink");
        let _ = writeln!(sink, "{line}");
        let _ = sink.flush();
    }

    /// Append one event carrying a request-id correlator in `"req"` —
    /// the serving path's end-to-end trace key: every `job_*` event a
    /// request causes (served, enqueued, search done) shares the id of
    /// the request that caused it, so one grep of the log reconstructs
    /// the request's whole life. Empty when no originator is known
    /// (e.g. a search completing after its requester was shed).
    pub fn emit_traced(&self, event: &str, req: &str, fields: Vec<(&str, Json)>) {
        let mut all = vec![("req", Json::str(req))];
        all.extend(fields);
        self.emit(event, all);
    }
}

fn unix_now() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

struct SharedVecWriter(std::sync::Arc<Mutex<Vec<u8>>>);

impl Write for SharedVecWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().expect("vec writer").extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_valid_jsonl() {
        let (log, buf) = EventLog::to_vec();
        log.emit("search_started", vec![("workload", Json::str("MM1"))]);
        log.emit("round_done", vec![("round", Json::num(3.0)), ("k", Json::num(0.8))]);
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = Json::parse(line).expect("valid json");
            assert!(v.get("event").is_some());
            assert!(v.get("ts_unix").is_some());
        }
        let second = Json::parse(text.lines().nth(1).unwrap()).unwrap();
        assert_eq!(second.get("k").unwrap().as_f64(), Some(0.8));
    }

    #[test]
    fn traced_events_carry_the_request_id() {
        let (log, buf) = EventLog::to_vec();
        log.emit_traced("job_served", "req-42", vec![("key", Json::str("k1"))]);
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let v = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("req").unwrap().as_str(), Some("req-42"));
        assert_eq!(v.get("key").unwrap().as_str(), Some("k1"));
        assert_eq!(v.get("event").unwrap().as_str(), Some("job_served"));
    }
}
