//! L3 coordination: the multi-device search driver (worker pool with
//! bounded-queue backpressure), suite metrics, and the JSONL event log.

pub mod driver;
pub mod events;
pub mod metrics;
pub mod workers;

pub use driver::{Driver, DriverConfig};
pub use events::EventLog;
pub use metrics::SuiteMetrics;
pub use workers::{JobResult, PoolEvent, SearchJob, WorkerPool};
