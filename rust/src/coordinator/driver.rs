//! The suite driver: the top-level L3 entry point that the CLI and the
//! experiments use to run batches of searches across the worker pool,
//! with event logging and aggregate metrics.

use super::events::EventLog;
use super::metrics::SuiteMetrics;
use super::workers::{JobResult, SearchJob, WorkerPool};
use crate::store::TuningStore;
use crate::util::Json;

/// Driver configuration.
#[derive(Debug, Clone)]
pub struct DriverConfig {
    /// Parallel search workers (simulated GPUs in the tuning fleet).
    pub n_workers: usize,
    /// Bounded job-queue capacity (backpressure threshold).
    pub queue_cap: usize,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            n_workers: crate::util::parallel::default_workers().min(8),
            queue_cap: 16,
        }
    }
}

/// Suite driver with optional JSONL event log.
pub struct Driver {
    cfg: DriverConfig,
    log: Option<EventLog>,
}

impl Driver {
    pub fn new(cfg: DriverConfig) -> Driver {
        Driver { cfg, log: None }
    }

    pub fn with_log(mut self, log: EventLog) -> Driver {
        self.log = Some(log);
        self
    }

    /// Run all jobs; returns (results in submission order, aggregate
    /// metrics).
    pub fn run_suite(&self, jobs: Vec<SearchJob>) -> (Vec<JobResult>, SuiteMetrics) {
        if let Some(log) = &self.log {
            log.emit(
                "suite_started",
                vec![
                    ("n_jobs", Json::num(jobs.len() as f64)),
                    ("n_workers", Json::num(self.cfg.n_workers as f64)),
                ],
            );
        }
        let mut pool = WorkerPool::new(self.cfg.n_workers, self.cfg.queue_cap);
        let mut cached: Vec<JobResult> = Vec::new();
        // One parsed store per distinct dir for the whole suite, shared
        // with the workers as an `Arc` snapshot (parse-once plumbing):
        // hits reflect the store as of submission; workers append their
        // own outcomes to the file as they finish without reopening it.
        let mut stores: std::collections::HashMap<String, Option<std::sync::Arc<TuningStore>>> =
            std::collections::HashMap::new();
        for (index, job) in jobs.into_iter().enumerate() {
            // Consult the tuning store before dispatching: an exact hit
            // short-circuits the job entirely — no worker, no clock.
            let snapshot = job.cfg.store.dir.as_ref().and_then(|dir| {
                stores
                    .entry(dir.clone())
                    .or_insert_with(|| {
                        TuningStore::open(std::path::Path::new(dir)).ok().map(std::sync::Arc::new)
                    })
                    .clone()
            });
            let hit = snapshot
                .as_ref()
                .and_then(|s| s.exact_hit(job.workload, &job.cfg))
                .map(|rec| rec.to_outcome());
            if let Some(outcome) = hit {
                if let Some(log) = &self.log {
                    log.emit(
                        "job_cache_hit",
                        vec![
                            ("name", Json::str(job.name.clone())),
                            ("workload", Json::str(job.workload.to_string())),
                            ("mode", Json::str(job.cfg.mode.name())),
                            ("best_energy_mj", Json::num(outcome.best.energy_j * 1e3)),
                        ],
                    );
                }
                cached.push(JobResult {
                    index,
                    name: job.name,
                    cfg: job.cfg,
                    outcome,
                    worker: 0,
                    cached: true,
                });
                continue;
            }
            if let Some(log) = &self.log {
                log.emit(
                    "job_submitted",
                    vec![
                        ("name", Json::str(job.name.clone())),
                        ("workload", Json::str(job.workload.to_string())),
                        ("mode", Json::str(job.cfg.mode.name())),
                    ],
                );
            }
            // Workers run the full store flow themselves (warm-start +
            // write-back) against the shared snapshot; without a store
            // configured they run the stateless paper flow.
            pool.submit_at_with_snapshot(index, job, snapshot);
        }
        let mut results = pool.finish();
        results.extend(cached);
        results.sort_by_key(|r| r.index);

        let mut metrics = SuiteMetrics::default();
        for r in &results {
            if r.cached {
                // A replayed cache hit is not a search: count it (and
                // its zero clock) separately.
                metrics.n_cache_hits += 1;
                metrics.absorb_clock(&r.outcome.clock);
            } else {
                metrics.absorb(&r.outcome);
            }
            if let Some(log) = &self.log {
                log.emit(
                    "job_done",
                    vec![
                        ("name", Json::str(r.name.clone())),
                        ("worker", Json::num(r.worker as f64)),
                        ("cached", Json::Bool(r.cached)),
                        ("best_latency_ms", Json::num(r.outcome.best.latency_s * 1e3)),
                        ("best_energy_mj", Json::num(r.outcome.best.energy_j * 1e3)),
                        ("best_power_w", Json::num(r.outcome.best.avg_power_w)),
                        (
                            "n_energy_measurements",
                            Json::num(r.outcome.n_energy_measurements() as f64),
                        ),
                        ("sim_time_s", Json::num(r.outcome.clock.total_s)),
                    ],
                );
            }
        }
        if let Some(log) = &self.log {
            log.emit("suite_done", vec![("summary", Json::str(metrics.summary()))]);
        }
        (results, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuArch, SearchConfig, SearchMode};
    use crate::workload::suites;

    #[test]
    fn driver_runs_suite_with_event_log() {
        let (log, buf) = EventLog::to_vec();
        let driver =
            Driver::new(DriverConfig { n_workers: 2, queue_cap: 2 }).with_log(log);
        let cfg = SearchConfig {
            gpu: GpuArch::A100,
            mode: SearchMode::EnergyAware,
            population: 24,
            m_latency_keep: 6,
            rounds: 3,
            patience: 0,
            ..Default::default()
        };
        let jobs = vec![
            SearchJob { name: "MM1".into(), workload: suites::MM1, cfg: cfg.clone() },
            SearchJob { name: "MV3".into(), workload: suites::MV3, cfg },
        ];
        let (results, metrics) = driver.run_suite(jobs);
        assert_eq!(results.len(), 2);
        assert_eq!(metrics.n_searches, 2);

        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let events: Vec<String> = text
            .lines()
            .map(|l| {
                Json::parse(l).unwrap().get("event").unwrap().as_str().unwrap().to_string()
            })
            .collect();
        assert_eq!(events[0], "suite_started");
        assert_eq!(events.last().unwrap(), "suite_done");
        assert_eq!(events.iter().filter(|e| *e == "job_done").count(), 2);
    }

    #[test]
    fn driver_serves_exact_hits_from_the_store() {
        let dir = std::env::temp_dir()
            .join(format!("ecokernel_driver_cache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = SearchConfig {
            gpu: GpuArch::A100,
            mode: SearchMode::EnergyAware,
            population: 24,
            m_latency_keep: 6,
            rounds: 3,
            patience: 0,
            ..Default::default()
        };
        cfg.store.dir = Some(dir.to_string_lossy().into_owned());
        let driver = Driver::new(DriverConfig { n_workers: 1, queue_cap: 1 });
        let job = |name: &str| SearchJob {
            name: name.to_string(),
            workload: suites::MM1,
            cfg: cfg.clone(),
        };

        let (r1, m1) = driver.run_suite(vec![job("first")]);
        assert!(!r1[0].cached, "first run must search");
        assert_eq!(m1.n_cache_hits, 0);
        assert_eq!(m1.n_searches, 1);
        assert!(r1[0].outcome.n_energy_measurements() > 0);

        let (r2, m2) = driver.run_suite(vec![job("second")]);
        assert!(r2[0].cached, "second run must be a cache hit");
        assert_eq!(m2.n_cache_hits, 1);
        assert_eq!(m2.n_searches, 0, "a replayed hit is not a search");
        assert_eq!(r2[0].outcome.n_energy_measurements(), 0);
        assert_eq!(r2[0].outcome.clock.total_s, 0.0);
        assert_eq!(r2[0].outcome.best.schedule, r1[0].outcome.best.schedule);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
