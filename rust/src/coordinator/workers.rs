//! The measurement-worker pool: a bounded-queue, multi-device job
//! executor.
//!
//! The paper's framework tunes one operator per GPU; a tuning *cluster*
//! runs many searches across a pool of devices. This module models that
//! topology: `n_workers` OS threads, each owning one simulated GPU
//! (thermal state and measurement clock are per-device), pulling
//! [`SearchJob`]s from a bounded channel — submission blocks when the
//! queue is full (backpressure), exactly like a real tuning fleet.

use crate::config::SearchConfig;
use crate::search::{run_search, SearchOutcome};
use crate::workload::Workload;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One search to run on some device.
#[derive(Debug, Clone)]
pub struct SearchJob {
    /// Display/reporting name (e.g. "MM1/energy").
    pub name: String,
    pub workload: Workload,
    pub cfg: SearchConfig,
}

/// A completed job.
pub struct JobResult {
    pub index: usize,
    pub name: String,
    pub outcome: SearchOutcome,
    /// Which worker/device executed it (0 for cache hits, which never
    /// reach a device).
    pub worker: usize,
    /// True when the driver served this job from the tuning store
    /// without dispatching it.
    pub cached: bool,
}

/// Fixed-size pool of search workers over a bounded job queue.
pub struct WorkerPool {
    tx: Option<SyncSender<(usize, SearchJob)>>,
    results: Arc<Mutex<Vec<JobResult>>>,
    handles: Vec<JoinHandle<()>>,
    submitted: usize,
}

impl WorkerPool {
    /// Spawn `n_workers` workers with a queue bound of `queue_cap`.
    pub fn new(n_workers: usize, queue_cap: usize) -> WorkerPool {
        let (tx, rx) = sync_channel::<(usize, SearchJob)>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let results: Arc<Mutex<Vec<JobResult>>> = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for worker in 0..n_workers.max(1) {
            let rx: Arc<Mutex<Receiver<(usize, SearchJob)>>> = rx.clone();
            let results = results.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().expect("job queue");
                    guard.recv()
                };
                match job {
                    Ok((index, job)) => {
                        let outcome = run_search(job.workload, &job.cfg);
                        // run_search may itself have hit the tuning
                        // store (e.g. an identical earlier job in this
                        // suite wrote back first): report it as cached
                        // so suite metrics don't count a replay as a
                        // search.
                        let cached = outcome.is_cache_replay();
                        results.lock().expect("results").push(JobResult {
                            index,
                            name: job.name,
                            outcome,
                            worker,
                            cached,
                        });
                    }
                    Err(_) => break, // queue closed
                }
            }));
        }
        WorkerPool { tx: Some(tx), results, handles, submitted: 0 }
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn submit(&mut self, job: SearchJob) {
        let idx = self.submitted;
        self.submit_at(idx, job);
    }

    /// Submit a job under an explicit result index (used by the driver
    /// when some indices were already served from the tuning store).
    pub fn submit_at(&mut self, index: usize, job: SearchJob) {
        self.submitted = self.submitted.max(index) + 1;
        self.tx
            .as_ref()
            .expect("pool open")
            .send((index, job))
            .expect("workers alive");
    }

    /// Close the queue, join all workers, and return results in
    /// submission order.
    pub fn finish(mut self) -> Vec<JobResult> {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            h.join().expect("worker panicked");
        }
        let mut results =
            Arc::try_unwrap(self.results).map(|m| m.into_inner().expect("results")).unwrap_or_default();
        results.sort_by_key(|r| r.index);
        results
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuArch, SearchMode};
    use crate::workload::suites;

    fn quick_cfg(seed: u64, mode: SearchMode) -> SearchConfig {
        SearchConfig {
            gpu: GpuArch::A100,
            mode,
            population: 24,
            m_latency_keep: 6,
            rounds: 3,
            patience: 0,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn pool_runs_jobs_and_preserves_order() {
        let mut pool = WorkerPool::new(4, 2);
        let jobs = [
            ("MM1", suites::MM1),
            ("MV3", suites::MV3),
            ("CONV2", suites::CONV2),
            ("MM3", suites::MM3),
        ];
        for (i, (name, w)) in jobs.iter().enumerate() {
            pool.submit(SearchJob {
                name: name.to_string(),
                workload: *w,
                cfg: quick_cfg(i as u64, SearchMode::EnergyAware),
            });
        }
        let results = pool.finish();
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.name, jobs[i].0);
            assert!(r.outcome.best.energy_j > 0.0);
        }
    }

    #[test]
    fn pool_results_match_serial_execution() {
        // Parallel execution must not change outcomes (per-job RNG).
        let cfg = quick_cfg(9, SearchMode::EnergyAware);
        let serial = run_search(suites::MM1, &cfg);
        let mut pool = WorkerPool::new(3, 1);
        for _ in 0..3 {
            pool.submit(SearchJob {
                name: "mm1".into(),
                workload: suites::MM1,
                cfg: cfg.clone(),
            });
        }
        let results = pool.finish();
        for r in &results {
            assert_eq!(r.outcome.best.schedule, serial.best.schedule);
            assert_eq!(r.outcome.best.energy_j, serial.best.energy_j);
        }
    }

    #[test]
    fn single_worker_pool_works() {
        let mut pool = WorkerPool::new(1, 1);
        pool.submit(SearchJob {
            name: "one".into(),
            workload: suites::MM1,
            cfg: quick_cfg(1, SearchMode::LatencyOnly),
        });
        let results = pool.finish();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].worker, 0);
    }
}
