//! The measurement-worker pool: a bounded-queue, multi-device job
//! executor.
//!
//! The paper's framework tunes one operator per GPU; a tuning *cluster*
//! runs many searches across a pool of devices. This module models that
//! topology: `n_workers` OS threads, each owning one simulated GPU
//! (thermal state and measurement clock are per-device), pulling
//! [`SearchJob`]s from a bounded channel — submission blocks when the
//! queue is full (backpressure), exactly like a real tuning fleet.

use crate::config::SearchConfig;
use crate::search::{run_search, run_search_with_snapshot, SearchOutcome};
use crate::store::TuningStore;
use crate::workload::Workload;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One search to run on some device.
#[derive(Debug, Clone)]
pub struct SearchJob {
    /// Display/reporting name (e.g. "MM1/energy").
    pub name: String,
    pub workload: Workload,
    pub cfg: SearchConfig,
}

/// A completed job.
pub struct JobResult {
    pub index: usize,
    pub name: String,
    /// The config the job ran with (the daemon rebuilds tuning records
    /// from outcome + config on write-back).
    pub cfg: SearchConfig,
    pub outcome: SearchOutcome,
    /// Which worker/device executed it (0 for cache hits, which never
    /// reach a device).
    pub worker: usize,
    /// True when the driver served this job from the tuning store
    /// without dispatching it.
    pub cached: bool,
}

/// What travels down the job queue: the result index, the job, and an
/// optional shared parsed-store snapshot (ROADMAP "Store parse-once
/// plumbing") — with a snapshot the worker consults it instead of
/// re-reading the whole JSONL file per job.
type QueuedJob = (usize, SearchJob, Option<Arc<TuningStore>>);

/// A worker-pool notification streamed to a result sink.
pub enum PoolEvent {
    /// The search finished.
    Done(JobResult),
    /// The search panicked. Carries the job's identity so the owner can
    /// release anything keyed on it (the daemon's in-flight
    /// reservation) instead of leaking it for the pool's lifetime.
    Failed { index: usize, name: String, cfg: SearchConfig, workload: Workload, error: String },
}

/// Fixed-size pool of search workers over a bounded job queue.
pub struct WorkerPool {
    tx: Option<SyncSender<QueuedJob>>,
    results: Arc<Mutex<Vec<JobResult>>>,
    handles: Vec<JoinHandle<()>>,
    submitted: usize,
    /// Jobs accepted (queued or running) and not yet completed — the
    /// serving daemon's real `queue_depth` stat.
    depth: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn `n_workers` workers with a queue bound of `queue_cap`.
    pub fn new(n_workers: usize, queue_cap: usize) -> WorkerPool {
        Self::spawn(n_workers, queue_cap, None)
    }

    /// Like [`WorkerPool::new`], but completed jobs are streamed into
    /// `sink` as they finish instead of being collected for
    /// [`WorkerPool::finish`] — the serving daemon's write-back path.
    /// A panicking search is reported as [`PoolEvent::Failed`] (the
    /// worker survives). The sink disconnects once every worker has
    /// exited.
    pub fn with_sink(n_workers: usize, queue_cap: usize, sink: Sender<PoolEvent>) -> WorkerPool {
        Self::spawn(n_workers, queue_cap, Some(sink))
    }

    fn spawn(n_workers: usize, queue_cap: usize, sink: Option<Sender<PoolEvent>>) -> WorkerPool {
        let (tx, rx) = sync_channel::<QueuedJob>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let results: Arc<Mutex<Vec<JobResult>>> = Arc::new(Mutex::new(Vec::new()));
        let depth: Arc<AtomicUsize> = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for worker in 0..n_workers.max(1) {
            let rx: Arc<Mutex<Receiver<QueuedJob>>> = rx.clone();
            let results = results.clone();
            let sink = sink.clone();
            let depth = depth.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().expect("job queue");
                    guard.recv()
                };
                match job {
                    Ok((index, job, snapshot)) => {
                        let SearchJob { name, workload, cfg } = job;
                        let outcome =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                match &snapshot {
                                    Some(snap) => run_search_with_snapshot(workload, &cfg, snap),
                                    None => run_search(workload, &cfg),
                                }
                            }));
                        match outcome {
                            Ok(outcome) => {
                                // The search may have been served as a
                                // store replay — from the shared
                                // snapshot, or (on the snapshot-less
                                // path, which reopens per job) from an
                                // identical earlier job's write-back.
                                // Report it as cached so suite metrics
                                // don't count a replay as a search.
                                // Note the snapshot is fixed at
                                // submission: duplicate in-flight jobs
                                // each search rather than racing on the
                                // first write-back.
                                let cached = outcome.is_cache_replay();
                                let result =
                                    JobResult { index, name, cfg, outcome, worker, cached };
                                match &sink {
                                    Some(tx) => {
                                        let _ = tx.send(PoolEvent::Done(result));
                                    }
                                    None => results.lock().expect("results").push(result),
                                }
                                depth.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(panic) => match &sink {
                                Some(tx) => {
                                    let error = panic_message(panic.as_ref());
                                    eprintln!(
                                        "worker {worker}: search '{name}' panicked: {error}"
                                    );
                                    let _ = tx.send(PoolEvent::Failed {
                                        index,
                                        name,
                                        cfg,
                                        workload,
                                        error,
                                    });
                                    depth.fetch_sub(1, Ordering::SeqCst);
                                }
                                // Batch mode keeps the old contract:
                                // finish() panics on a worker panic.
                                None => std::panic::resume_unwind(panic),
                            },
                        }
                    }
                    Err(_) => break, // queue closed
                }
            }));
        }
        WorkerPool { tx: Some(tx), results, handles, submitted: 0, depth }
    }

    /// Jobs accepted by the pool (queued or running) and not yet
    /// finished.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    /// The live counter behind [`WorkerPool::queue_depth`]: the serving
    /// daemon reads it from its stats path without locking the pool.
    pub fn depth_counter(&self) -> Arc<AtomicUsize> {
        self.depth.clone()
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn submit(&mut self, job: SearchJob) {
        let idx = self.submitted;
        self.submit_at(idx, job);
    }

    /// Submit a job under an explicit result index (used by the driver
    /// when some indices were already served from the tuning store).
    pub fn submit_at(&mut self, index: usize, job: SearchJob) {
        self.submit_at_with_snapshot(index, job, None);
    }

    /// Submit a job that consults a shared parsed store snapshot
    /// instead of reopening the store file.
    pub fn submit_at_with_snapshot(
        &mut self,
        index: usize,
        job: SearchJob,
        snapshot: Option<Arc<TuningStore>>,
    ) {
        self.submitted = self.submitted.max(index) + 1;
        self.depth.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool open")
            .send((index, job, snapshot))
            .expect("workers alive");
    }

    /// [`WorkerPool::submit`] with a shared store snapshot.
    pub fn submit_with_snapshot(&mut self, job: SearchJob, snapshot: Option<Arc<TuningStore>>) {
        let idx = self.submitted;
        self.submit_at_with_snapshot(idx, job, snapshot);
    }

    /// Non-blocking submit: returns `false` (dropping the job) when the
    /// queue is full. The serving daemon load-sheds with this so a miss
    /// reply is never delayed by a full search queue.
    pub fn try_submit_with_snapshot(
        &mut self,
        job: SearchJob,
        snapshot: Option<Arc<TuningStore>>,
    ) -> bool {
        let index = self.submitted;
        let tx = self.tx.as_ref().expect("pool open");
        // Counted BEFORE the send: a worker that dequeues and finishes
        // instantly must never decrement below zero.
        self.depth.fetch_add(1, Ordering::SeqCst);
        match tx.try_send((index, job, snapshot)) {
            Ok(()) => {
                self.submitted = index + 1;
                true
            }
            Err(_) => {
                self.depth.fetch_sub(1, Ordering::SeqCst);
                false // queue full (or workers gone)
            }
        }
    }

    /// Close the queue, join all workers, and return results in
    /// submission order. In batch (non-sink) mode a worker panic
    /// propagates here.
    pub fn finish(mut self) -> Vec<JobResult> {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            h.join().expect("worker panicked");
        }
        let mut results = Arc::try_unwrap(self.results)
            .map(|m| m.into_inner().expect("results"))
            .unwrap_or_default();
        results.sort_by_key(|r| r.index);
        results
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuArch, SearchMode};
    use crate::workload::suites;

    fn quick_cfg(seed: u64, mode: SearchMode) -> SearchConfig {
        SearchConfig {
            gpu: GpuArch::A100,
            mode,
            population: 24,
            m_latency_keep: 6,
            rounds: 3,
            patience: 0,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn pool_runs_jobs_and_preserves_order() {
        let mut pool = WorkerPool::new(4, 2);
        let jobs = [
            ("MM1", suites::MM1),
            ("MV3", suites::MV3),
            ("CONV2", suites::CONV2),
            ("MM3", suites::MM3),
        ];
        for (i, (name, w)) in jobs.iter().enumerate() {
            pool.submit(SearchJob {
                name: name.to_string(),
                workload: *w,
                cfg: quick_cfg(i as u64, SearchMode::EnergyAware),
            });
        }
        let results = pool.finish();
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.name, jobs[i].0);
            assert!(r.outcome.best.energy_j > 0.0);
        }
    }

    #[test]
    fn pool_results_match_serial_execution() {
        // Parallel execution must not change outcomes (per-job RNG).
        let cfg = quick_cfg(9, SearchMode::EnergyAware);
        let serial = run_search(suites::MM1, &cfg);
        let mut pool = WorkerPool::new(3, 1);
        for _ in 0..3 {
            pool.submit(SearchJob {
                name: "mm1".into(),
                workload: suites::MM1,
                cfg: cfg.clone(),
            });
        }
        let results = pool.finish();
        for r in &results {
            assert_eq!(r.outcome.best.schedule, serial.best.schedule);
            assert_eq!(r.outcome.best.energy_j, serial.best.energy_j);
        }
    }

    #[test]
    fn shared_snapshot_serves_hits_without_reopening_the_store() {
        let dir = std::env::temp_dir()
            .join(format!("ecokernel_pool_snapshot_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = quick_cfg(21, SearchMode::EnergyAware);
        cfg.store.dir = Some(dir.to_string_lossy().into_owned());

        // Populate the store with one finished search.
        let first = run_search(suites::MM1, &cfg);
        assert!(first.n_energy_measurements() > 0);

        // Parse once, share the snapshot, then DELETE the store file:
        // a worker that re-opened per job would now run a cold search,
        // a snapshot-driven worker still replays the hit.
        let snapshot = Arc::new(TuningStore::open(&dir).unwrap());
        std::fs::remove_file(dir.join(crate::store::STORE_FILE)).unwrap();
        let mut pool = WorkerPool::new(1, 1);
        pool.submit_with_snapshot(
            SearchJob { name: "mm1".into(), workload: suites::MM1, cfg: cfg.clone() },
            Some(snapshot),
        );
        let results = pool.finish();
        assert!(results[0].cached, "snapshot hit is a cache replay");
        assert_eq!(results[0].outcome.n_energy_measurements(), 0);
        assert_eq!(results[0].outcome.best.schedule, first.best.schedule);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_miss_searches_and_appends_write_back() {
        let dir = std::env::temp_dir()
            .join(format!("ecokernel_pool_snapmiss_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = quick_cfg(22, SearchMode::EnergyAware);
        cfg.store.dir = Some(dir.to_string_lossy().into_owned());

        let snapshot = Arc::new(TuningStore::open(&dir).unwrap());
        assert!(snapshot.is_empty());
        let mut pool = WorkerPool::new(1, 1);
        pool.submit_with_snapshot(
            SearchJob { name: "mv3".into(), workload: suites::MV3, cfg: cfg.clone() },
            Some(snapshot),
        );
        let results = pool.finish();
        assert!(!results[0].cached);
        assert!(results[0].outcome.n_energy_measurements() > 0);
        // Write-back appended to the file even though the snapshot is
        // immutable: reopening sees the record.
        let reopened = TuningStore::open(&dir).unwrap();
        assert!(reopened.exact_hit(suites::MV3, &cfg).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sink_streams_results_and_finish_returns_nothing() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut pool = WorkerPool::with_sink(2, 2, tx);
        for (i, w) in [suites::MM1, suites::MV3, suites::CONV2].iter().enumerate() {
            pool.submit(SearchJob {
                name: format!("job{i}"),
                workload: *w,
                cfg: quick_cfg(i as u64, SearchMode::LatencyOnly),
            });
        }
        let leftover = pool.finish();
        assert!(leftover.is_empty(), "sink mode collects nothing");
        let mut streamed: Vec<JobResult> = rx
            .iter()
            .map(|e| match e {
                PoolEvent::Done(r) => r,
                PoolEvent::Failed { name, error, .. } => panic!("{name} failed: {error}"),
            })
            .collect();
        assert_eq!(streamed.len(), 3, "every result reached the sink");
        streamed.sort_by_key(|r| r.index);
        for (i, r) in streamed.iter().enumerate() {
            assert_eq!(r.name, format!("job{i}"));
            assert_eq!(r.cfg.seed, i as u64, "job config travels with the result");
        }
    }

    #[test]
    fn sink_reports_panicked_jobs_as_failed_and_worker_survives() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut pool = WorkerPool::with_sink(1, 2, tx);
        // run_search panics on an invalid config — the worker must
        // survive it, report the failure, and still run the next job.
        let mut bad = quick_cfg(0, SearchMode::EnergyAware);
        bad.population = 0;
        pool.submit(SearchJob { name: "bad".into(), workload: suites::MM1, cfg: bad });
        pool.submit(SearchJob {
            name: "good".into(),
            workload: suites::MM1,
            cfg: quick_cfg(1, SearchMode::LatencyOnly),
        });
        pool.finish();
        let events: Vec<PoolEvent> = rx.iter().collect();
        assert_eq!(events.len(), 2);
        match &events[0] {
            PoolEvent::Failed { name, error, workload, .. } => {
                assert_eq!(name, "bad");
                assert_eq!(*workload, suites::MM1);
                assert!(error.contains("population"), "{error}");
            }
            PoolEvent::Done(_) => panic!("invalid config must fail, not finish"),
        }
        match &events[1] {
            PoolEvent::Done(r) => assert_eq!(r.name, "good"),
            PoolEvent::Failed { error, .. } => panic!("good job failed: {error}"),
        }
    }

    #[test]
    fn queue_depth_returns_to_zero_when_all_jobs_finish() {
        let mut pool = WorkerPool::new(2, 2);
        assert_eq!(pool.queue_depth(), 0);
        let depth = pool.depth_counter();
        for seed in 0..3 {
            pool.submit(SearchJob {
                name: format!("d{seed}"),
                workload: suites::MM1,
                cfg: quick_cfg(seed, SearchMode::LatencyOnly),
            });
        }
        let results = pool.finish();
        assert_eq!(results.len(), 3);
        assert_eq!(depth.load(Ordering::SeqCst), 0, "every accepted job was counted back out");
    }

    #[test]
    fn single_worker_pool_works() {
        let mut pool = WorkerPool::new(1, 1);
        pool.submit(SearchJob {
            name: "one".into(),
            workload: suites::MM1,
            cfg: quick_cfg(1, SearchMode::LatencyOnly),
        });
        let results = pool.finish();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].worker, 0);
    }
}
