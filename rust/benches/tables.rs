//! End-to-end regeneration benches: one per paper table. Each bench
//! runs the full experiment pipeline (searches + measurement + report)
//! and prints both the timing and the regenerated rows.
//!
//! `cargo bench --bench tables` (quick effort; pass --paper via
//! ECOKERNEL_BENCH_PAPER=1 for full effort).

mod bench_util;

use bench_util::bench_once;
use ecokernel::experiments::{self, Effort};

fn effort() -> Effort {
    if std::env::var("ECOKERNEL_BENCH_PAPER").is_ok() {
        Effort::Paper
    } else {
        Effort::Quick
    }
}

fn main() {
    let e = effort();
    println!("== table regeneration benches (effort: {e:?}) ==\n");

    let t2 = bench_once("table2 (11 ops x 2 searches, a100)", || experiments::table2(e));
    println!("{}\n", t2.render("Table 2"));

    let t3 = bench_once("table3 (3 ops x 2 searches, rtx4090)", || experiments::table3(e));
    println!("{}\n", t3.render("Table 3"));

    let t4 = bench_once("table4 (4 ops vs cublas-sim)", || experiments::table4(e));
    println!("{}\n", t4.render());

    let t5 = bench_once("table5 (case-study profile)", || experiments::table5(e));
    println!("{}\n", t5.render());

    println!("{}", experiments::table1());
}
