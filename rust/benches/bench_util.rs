//! Shared micro-bench harness (offline build: no criterion). Measures
//! wall time over warm-up + timed iterations and prints a stable,
//! grep-friendly report line per benchmark.

use std::time::Instant;

/// Time `f` and print `name: <mean> per iter (<iters> iters, total)`.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) {
    // Warm-up.
    let warm = (iters / 10).max(1);
    for _ in 0..warm {
        std::hint::black_box(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    let total = t0.elapsed();
    let per = total.as_secs_f64() / iters as f64;
    println!(
        "bench {name:<40} {:>12}/iter  ({iters} iters, {:.2}s total)",
        fmt_duration(per),
        total.as_secs_f64()
    );
}

/// Time one execution of `f` (for end-to-end experiment benches).
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("bench {name:<40} {:>12}  (single run)", fmt_duration(t0.elapsed().as_secs_f64()));
    out
}

pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}
