//! Hot-path microbenches for the perf pass (EXPERIMENTS.md §Perf):
//!
//! * `sim_eval`       — full simulator evaluation (called per measured kernel)
//! * `sim_latency`    — the latency-only fast path (called per genetic child)
//! * `featurize`      — §5.4 feature extraction
//! * `gbdt_train`     — cost-model fit (per-round `ModelUpdate`)
//! * `gbdt_predict`   — batch prediction over one generation
//! * `ga_round`       — reproduce + latency-rank one full generation
//! * `pjrt_exec`      — one artifact execution through PJRT (if built)

mod bench_util;

use bench_util::bench;
use ecokernel::config::{GpuArch, SearchConfig};
use ecokernel::costmodel::EnergyCostModel;
use ecokernel::features::featurize;
use ecokernel::nvml::NvmlMeter;
use ecokernel::schedule::{space::ScheduleSpace, Candidate};
use ecokernel::search;
use ecokernel::sim;
use ecokernel::util::Rng;
use ecokernel::workload::suites;

fn main() {
    let spec = GpuArch::A100.spec();
    let w = suites::MM1;
    let g = w.gemm_view();
    let space = ScheduleSpace::new(w, &spec);
    let mut rng = Rng::seed_from_u64(1);
    let scheds = space.sample_n(&mut rng, 256);

    // sim_eval: full power+latency+profile evaluation.
    let mut i = 0;
    bench("sim_eval (full)", 20_000, || {
        i = (i + 1) % scheds.len();
        sim::evaluate(&g, &scheds[i], &spec)
    });

    // sim_latency: the genetic inner loop.
    let mut j = 0;
    bench("sim_latency (fast path)", 50_000, || {
        j = (j + 1) % scheds.len();
        sim::evaluate_latency(&g, &scheds[j], &spec)
    });

    // featurize.
    let cands: Vec<Candidate> = scheds.iter().map(|s| Candidate::new(w, *s)).collect();
    let mut k = 0;
    bench("featurize (36-dim)", 20_000, || {
        k = (k + 1) % cands.len();
        featurize(&cands[k], &spec)
    });

    // gbdt_train on a realistic mid-search dataset (~256 samples).
    let samples: Vec<(ecokernel::features::FeatureVector, f64)> = cands
        .iter()
        .map(|c| (featurize(c, &spec), sim::evaluate_candidate(c, &spec).energy_j))
        .collect();
    bench("gbdt_train (256 samples, 80 trees)", 10, || {
        let mut m = EnergyCostModel::new(Default::default());
        m.update(&samples, &mut Rng::seed_from_u64(2));
        m
    });

    // gbdt_predict over one generation.
    let mut model = EnergyCostModel::new(Default::default());
    model.update(&samples, &mut Rng::seed_from_u64(2));
    let feats: Vec<ecokernel::features::FeatureVector> =
        cands.iter().map(|c| featurize(c, &spec)).collect();
    bench("gbdt_predict (batch of 256)", 2_000, || model.predict_energy_batch(&feats));

    // ga_round: reproduce 128 children + latency-rank them.
    let cfg = SearchConfig { population: 128, m_latency_keep: 32, ..Default::default() };
    let parents = scheds[..16].to_vec();
    let mut meter = NvmlMeter::warmed(spec.clone(), cfg.nvml.clone());
    let mut ga_rng = Rng::seed_from_u64(3);
    bench("ga_round (reproduce 128 + rank)", 200, || {
        let gen = search::genetic::reproduce(&space, &parents, &cfg, &mut ga_rng);
        search::latency_eva_and_pick(w, &gen, cfg.m_latency_keep, &mut meter, &mut ga_rng)
    });

    // pjrt_exec: one real artifact execution (skipped without artifacts).
    let dir = ecokernel::runtime::ArtifactRegistry::default_dir();
    if let Ok(reg) = ecokernel::runtime::ArtifactRegistry::open(&dir) {
        if let Some(meta) = reg.get("mm_b1_m512_n512_k512", "bm64_bn64_bk16") {
            let kernel = reg.load(meta).expect("compile");
            let x = vec![0.01f32; 512 * 512];
            let shape = [512usize, 512];
            bench("pjrt_exec (mm 512^3, bm64_bn64_bk16)", 3, || {
                kernel.run_f32(&[(&x, &shape), (&x, &shape)]).expect("exec")
            });
        }
    } else {
        println!("bench pjrt_exec skipped (run `make artifacts`)");
    }
}
