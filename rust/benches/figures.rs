//! End-to-end regeneration benches: one per paper figure.
//!
//! `cargo bench --bench figures` (ECOKERNEL_BENCH_PAPER=1 for full
//! effort).

mod bench_util;

use bench_util::bench_once;
use ecokernel::experiments::{self, Effort};

fn effort() -> Effort {
    if std::env::var("ECOKERNEL_BENCH_PAPER").is_ok() {
        Effort::Paper
    } else {
        Effort::Quick
    }
}

fn main() {
    let e = effort();
    println!("== figure regeneration benches (effort: {e:?}) ==\n");

    let f2 = bench_once("fig2 (conv scatter, p100)", || experiments::fig2(e));
    println!("{}\n", f2.summary());

    let f3 = bench_once("fig3 (latency-power sweep, a100)", || experiments::fig3(e));
    println!("{}\n", f3.summary());

    let f4 = bench_once("fig4 (cost-model 80/20 eval)", || experiments::fig4(e));
    println!("{}\n", f4.summary());

    let f5 = bench_once("fig5 (nvml-only vs cost-model)", || experiments::fig5(e));
    println!("{}", f5.render());
}
