//! Integration: the wire-v2 data plane end to end (the ISSUE 10
//! acceptance criteria) — `hello` negotiation and the clean downgrade
//! against a daemon that predates it, all three historical line-JSON
//! frame generations still parsing, and the out-of-order reply pin: a
//! slow miss and a fast hit multiplexed on ONE binary connection, the
//! hit replying first, over both `unix:` and `tcp:`.
#![cfg(unix)]

use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
use ecokernel::fleet::Stream;
use ecokernel::serve::{
    wire, wire_name, Daemon, DaemonConfig, DaemonHandle, KernelReply, Op, Response, ServeAddr,
    ServeClient, ServeSource, ServeTier, StatsReply, WIRE_VERSION,
};
use ecokernel::workload::suites;
use std::io::{BufRead, BufReader, Read, Write};
use std::path::{Path, PathBuf};
use std::time::Duration;

const DRAIN_TIMEOUT: Duration = Duration::from_secs(120);

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ecokernel_wire_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A quick daemon on the given address: small searches, small pool.
fn spawn_daemon(tag: &str, addr: ServeAddr) -> (DaemonHandle, PathBuf) {
    let dir = tmp_dir(tag);
    let mut search = SearchConfig {
        gpu: GpuArch::A100,
        mode: SearchMode::EnergyAware,
        population: 16,
        m_latency_keep: 4,
        rounds: 2,
        patience: 0,
        seed: 11,
        ..Default::default()
    };
    search.serve.n_workers = 1;
    search.serve.n_shards = 4;
    let addr = match addr {
        ServeAddr::Unix(_) => ServeAddr::Unix(dir.join("ecokernel.sock")),
        tcp => tcp,
    };
    let handle =
        Daemon::spawn(DaemonConfig { addr, store_dir: dir.clone(), search }, None).unwrap();
    (handle, dir)
}

fn stop(handle: DaemonHandle, dir: &Path) {
    let mut client = ServeClient::connect(&handle.addr).unwrap();
    client.shutdown().unwrap();
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(dir);
}

/// Warm one workload into the store so later requests are exact hits.
fn warm(addr: &ServeAddr) {
    let mut client = ServeClient::connect(addr).unwrap();
    let first = client
        .call(Op::GetKernel { workload: suites::MM1, gpu: None, mode: None, trace: None })
        .unwrap()
        .into_kernel()
        .unwrap();
    assert!(!first.hit);
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
}

// -- negotiation ------------------------------------------------------

/// The full upgrade path: `hello` grants binary, the same connection
/// then serves a miss (kind-2 via the slow lane), a hit (kind-2
/// inline), admin ops (kind-0 JSON), and a traced request (which rides
/// kind-0 because kind-1 carries no trace field).
#[test]
fn binary_negotiation_upgrades_and_serves() {
    let (handle, dir) = spawn_daemon("nego", ServeAddr::Unix(PathBuf::new()));
    let mut client = ServeClient::connect_negotiated(&handle.addr).unwrap();
    assert_eq!(client.wire(), wire_name::BINARY);
    // Re-negotiation is idempotent once granted.
    assert!(client.negotiate_binary().unwrap());

    let miss = client
        .call(Op::GetKernel { workload: suites::MM1, gpu: None, mode: None, trace: None })
        .unwrap()
        .into_kernel()
        .unwrap();
    assert!(!miss.hit);
    assert!(miss.enqueued);

    let drained = client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert_eq!(drained.n_searches_done, 1);

    let hit = client
        .call(Op::GetKernel { workload: suites::MM1, gpu: None, mode: None, trace: None })
        .unwrap()
        .into_kernel()
        .unwrap();
    assert!(hit.hit);
    assert_eq!(hit.source, ServeSource::Store);

    // A traced request works on the binary wire (kind-0 fallback).
    let traced = client
        .get_kernel_traced(suites::MM1, None, None, Some("00ff00ff00ff00ff"))
        .unwrap();
    assert!(traced.hit);

    // The negotiation and the frames it carried are visible in the
    // daemon's counters.
    let metrics = client.call(Op::Metrics).unwrap().into_metrics().unwrap();
    assert!(metrics.counter("n_hello") >= 1, "hello negotiations counted");
    assert!(metrics.counter("n_binary_frames") >= 4, "binary frames counted");

    stop(handle, &dir);
}

/// A daemon that never heard of `hello`: replies `bad_request`, and
/// the client downgrades to line-JSON without erroring — then keeps
/// using the same connection. The canned reply is a real pre-fleet
/// stats frame, so this doubles as a cross-generation compat check.
#[test]
fn old_daemon_downgrades_to_line_json() {
    let (listener, addr) =
        ecokernel::fleet::Listener::bind(&ServeAddr::Tcp("127.0.0.1:0".to_string())).unwrap();
    let fake = std::thread::spawn(move || {
        let stream = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut stream = stream;
        // Frame 1: the hello this daemon does not understand.
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"hello\""), "expected a hello, got {line}");
        stream
            .write_all(
                b"{\"v\":1,\"id\":\"c1\",\"ok\":false,\"error\":{\"code\":\"bad_request\",\"message\":\"unknown op 'hello'\"}}\n",
            )
            .unwrap();
        // Frame 2: a stats request, answered with a pre-fleet frame.
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"stats\""), "expected stats, got {line}");
        stream
            .write_all(
                b"{\"v\":1,\"id\":\"c2\",\"ok\":true,\"op\":\"stats\",\"stats\":{\"n_requests\":7,\"n_hits\":3,\"n_misses\":4,\"n_enqueued\":4,\"n_searches_done\":4,\"n_evicted_records\":0,\"queue_depth\":0,\"n_records\":4,\"n_shards\":4,\"hit_rate\":0.42,\"p50_reply_s\":0.001,\"p99_reply_s\":0.002,\"measurements_paid\":96}}\n",
            )
            .unwrap();
    });

    let mut client = ServeClient::connect(&addr).unwrap();
    let granted = client.negotiate_binary().unwrap();
    assert!(!granted, "an old daemon must downgrade, not error");
    assert_eq!(client.wire(), wire_name::LINE);

    let stats = client.call(Op::Stats).unwrap().into_stats().unwrap();
    assert_eq!(stats.n_requests, 7);
    assert_eq!(stats.measurements_paid, 96);
    // Fleet-era fields are absent in that generation: parsed as zero.
    assert_eq!(stats.n_shed, 0);
    assert_eq!(stats.pending_keys, 0);

    drop(client);
    fake.join().unwrap();
}

// -- historical frame generations -------------------------------------

const SCHEDULE_JSON: &str =
    "{\"tm\":8,\"tn\":8,\"rm\":4,\"rn\":4,\"tk\":16,\"uk\":2,\"vw\":4,\"sk\":1,\"sh\":true}";

/// All three line-JSON reply generations parse with today's client:
/// gen 1 (pre-tier — no `tier`, derived from `source`), gen 2
/// (pre-fleet stats — fleet counters absent, parsed as zero), and
/// gen 3 (the current frame, which must round-trip exactly).
#[test]
fn historical_frame_generations_parse() {
    // Gen 1: a kernel reply from before the serving-tier split.
    let gen1 = format!(
        "{{\"v\":1,\"id\":\"g1\",\"ok\":true,\"op\":\"get_kernel\",\"result\":\"hit\",\
         \"source\":\"store\",\"schedule\":{SCHEDULE_JSON},\"latency_s\":0.002,\
         \"energy_j\":0.4,\"avg_power_w\":200.0,\"enqueued\":false,\"queue_depth\":0,\
         \"reply_time_s\":0.0001}}"
    );
    match Response::parse_line(&gen1).unwrap() {
        Response::Kernel(r) => {
            assert!(r.hit);
            assert_eq!(r.tier, ServeTier::Exact, "tier derived from source on pre-tier frames");
        }
        other => panic!("gen-1 frame parsed as {other:?}"),
    }

    // Gen 2: a pre-fleet stats frame (no shed/coalesce/backlog/batch
    // counters, no uptime or shard maps).
    let gen2 = "{\"v\":1,\"id\":\"g2\",\"ok\":true,\"op\":\"stats\",\"stats\":{\
         \"n_requests\":1,\"n_hits\":0,\"n_misses\":1,\"n_enqueued\":1,\"n_searches_done\":0,\
         \"n_evicted_records\":0,\"queue_depth\":1,\"n_records\":0,\"n_shards\":4,\
         \"hit_rate\":0.0,\"p50_reply_s\":0.0,\"p99_reply_s\":0.0,\"measurements_paid\":0}}";
    match Response::parse_line(gen2).unwrap() {
        Response::Stats(s) => {
            assert_eq!(s.n_misses, 1);
            assert_eq!(s.n_batch_frames, 0);
            assert!(s.shard_records.is_empty());
        }
        other => panic!("gen-2 frame parsed as {other:?}"),
    }

    // Gen 3: the current generation round-trips bit-exactly, hello
    // ack included (`wire_v` advertises the binary framing version).
    let ack = Response::HelloAck { id: "g3".to_string(), wire: wire_name::BINARY.to_string() };
    let encoded = ack.to_json().to_string();
    assert!(encoded.contains(&format!("\"wire_v\":{WIRE_VERSION}")));
    assert_eq!(Response::parse_line(&encoded).unwrap(), ack);
}

// -- out-of-order replies ---------------------------------------------

/// Read one `\n`-terminated line from a raw stream, byte at a time
/// (the hello ack is the only line-framed byte sequence on this
/// connection, so simplicity beats buffering — a buffered reader
/// could steal the binary bytes that follow).
fn read_ack_line(stream: &mut Stream) -> String {
    let mut line = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        let n = stream.read(&mut byte).unwrap();
        assert!(n > 0, "daemon closed before the hello ack");
        if byte[0] == b'\n' {
            break;
        }
        line.push(byte[0]);
    }
    String::from_utf8(line).unwrap()
}

/// Read exactly `n` whole binary frames, in arrival order.
fn read_frames(stream: &mut Stream, n: usize) -> Vec<wire::Frame> {
    let mut frames = Vec::with_capacity(n);
    let mut rbuf: Vec<u8> = Vec::new();
    while frames.len() < n {
        match wire::Frame::decode(&rbuf).unwrap() {
            Some((frame, used)) => {
                rbuf.drain(..used);
                frames.push(frame);
            }
            None => {
                let mut chunk = [0u8; 8192];
                let got = stream.read(&mut chunk).unwrap();
                assert!(got > 0, "daemon closed mid-frame");
                rbuf.extend_from_slice(&chunk[..got]);
            }
        }
    }
    frames
}

/// THE head-of-line pin: one binary connection sends a slow miss
/// (tag 7) immediately followed by a fast hit (tag 8) in a single
/// write. The hit's reply must arrive FIRST — the miss is parked on
/// the slow lane and must not block its sibling. Raw frames (not
/// `call_many`) so physical arrival order is observable.
fn out_of_order_pin(tag: &str, addr: ServeAddr) {
    let (handle, dir) = spawn_daemon(tag, addr);
    warm(&handle.addr);

    let mut stream = Stream::connect(&handle.addr).unwrap();
    stream
        .write_all(b"{\"v\":1,\"op\":\"hello\",\"id\":\"h1\",\"wire\":\"binary\"}\n")
        .unwrap();
    let ack = read_ack_line(&mut stream);
    match Response::parse_line(&ack).unwrap() {
        Response::HelloAck { wire, .. } => assert_eq!(wire, wire_name::BINARY),
        other => panic!("expected a hello ack, got {other:?}"),
    }

    // One buffer, one write: miss first, hit second.
    let mut buf = Vec::new();
    wire::Frame {
        tag: 7,
        kind: wire::KIND_GET_KERNEL,
        payload: wire::encode_get_kernel(&suites::MM2, None, None),
    }
    .encode_into(&mut buf);
    wire::Frame {
        tag: 8,
        kind: wire::KIND_GET_KERNEL,
        payload: wire::encode_get_kernel(&suites::MM1, None, None),
    }
    .encode_into(&mut buf);
    stream.write_all(&buf).unwrap();
    stream.flush().unwrap();

    let frames = read_frames(&mut stream, 2);
    assert_eq!(
        frames[0].tag, 8,
        "the hit must reply before the miss that was written ahead of it"
    );
    assert_eq!(frames[1].tag, 7);
    for frame in &frames {
        assert_eq!(frame.kind, wire::KIND_KERNEL_REPLY);
    }
    let hit = wire::decode_kernel_reply(frames[0].tag, &frames[0].payload).unwrap();
    assert!(hit.hit);
    assert_eq!(hit.id, "t8");
    let miss = wire::decode_kernel_reply(frames[1].tag, &frames[1].payload).unwrap();
    assert!(!miss.hit);
    assert!(miss.enqueued);
    drop(stream);

    // The daemon saw the reorder and counted it.
    let mut client = ServeClient::connect(&handle.addr).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    let metrics = client.call(Op::Metrics).unwrap().into_metrics().unwrap();
    assert!(metrics.counter("n_ooo_replies") >= 1, "out-of-order reply counted");

    stop(handle, &dir);
}

#[test]
fn out_of_order_replies_over_unix() {
    out_of_order_pin("ooo_unix", ServeAddr::Unix(PathBuf::new()));
}

#[test]
fn out_of_order_replies_over_tcp() {
    out_of_order_pin("ooo_tcp", ServeAddr::Tcp("127.0.0.1:0".to_string()));
}

/// `call_many` on the binary wire: replies physically arrive out of
/// order (miss slow, hit fast) but the returned vector is positional.
#[test]
fn call_many_reorders_binary_replies() {
    let (handle, dir) = spawn_daemon("pipeline", ServeAddr::Unix(PathBuf::new()));
    warm(&handle.addr);

    let mut client = ServeClient::connect_negotiated(&handle.addr).unwrap();
    assert_eq!(client.wire(), wire_name::BINARY);
    let replies = client
        .call_many(vec![
            Op::GetKernel { workload: suites::MM3, gpu: None, mode: None, trace: None },
            Op::GetKernel { workload: suites::MM1, gpu: None, mode: None, trace: None },
        ])
        .unwrap();
    let replies: Vec<KernelReply> =
        replies.into_iter().map(|r| r.into_kernel().unwrap()).collect();
    assert!(!replies[0].hit, "slot 0 is the MM3 miss");
    assert!(replies[1].hit, "slot 1 is the warmed MM1 hit");
    assert_eq!(replies[1].tier, ServeTier::Exact);

    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    stop(handle, &dir);
}

/// Sanity for the helper types this file leans on.
#[test]
fn stats_reply_helper_shape() {
    let stats = StatsReply {
        id: "x".to_string(),
        n_requests: 0,
        n_hits: 0,
        n_misses: 0,
        n_enqueued: 0,
        n_searches_done: 0,
        n_evicted_records: 0,
        queue_depth: 0,
        n_records: 0,
        n_shards: 1,
        hit_rate: 0.0,
        p50_reply_s: 0.0,
        p99_reply_s: 0.0,
        measurements_paid: 0,
        n_shed: 0,
        n_fleet_coalesced: 0,
        n_static_tier: 0,
        backlog_len: 0,
        pending_keys: 0,
        n_writebacks_fenced: 0,
        n_writebacks_dropped: 0,
        n_batch_frames: 0,
        n_batch_requests: 0,
        n_notify_refresh: 0,
        n_poll_refresh: 0,
        uptime_s: 0.0,
        build_info: String::new(),
        shard_records: vec![],
        heat_histogram: vec![],
    };
    let line = stats.to_json().to_string();
    match Response::parse_line(&line).unwrap() {
        Response::Stats(parsed) => assert_eq!(parsed, stats),
        other => panic!("stats round-trip parsed as {other:?}"),
    }
}
