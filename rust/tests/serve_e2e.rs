//! Integration: the kernel-serving daemon end to end — miss → warm
//! guess + background search → exact hit with zero measurements,
//! protocol error handling over a real socket, eviction under per-GPU
//! quotas, and the served-vs-searched metrics (the ISSUE 2 acceptance
//! criteria).
#![cfg(unix)]

use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
use ecokernel::serve::{
    error_code, Daemon, DaemonConfig, DaemonHandle, HealthReply, HealthStatus, KernelReply,
    MetricsReply, Op, ServeAddr, ServeClient, ServeSource, ServeTier, StatsReply, TraceReply,
    HEALTH_VERSION,
};
use ecokernel::telemetry::{ledger_family_index, ledger_gpu_index};
use ecokernel::util::Json;
use ecokernel::workload::{suites, Workload};
use std::path::{Path, PathBuf};
use std::time::Duration;

const DRAIN_TIMEOUT: Duration = Duration::from_secs(120);

// Thin shims over the typed op API, so every test reads as one call
// per wire operation.

fn get_kernel(
    client: &mut ServeClient,
    workload: Workload,
    gpu: Option<GpuArch>,
    mode: Option<SearchMode>,
) -> anyhow::Result<KernelReply> {
    client.call(Op::GetKernel { workload, gpu, mode, trace: None })?.into_kernel()
}

fn stats(client: &mut ServeClient) -> anyhow::Result<StatsReply> {
    client.call(Op::Stats)?.into_stats()
}

fn metrics(client: &mut ServeClient) -> anyhow::Result<MetricsReply> {
    client.call(Op::Metrics)?.into_metrics()
}

fn traces(client: &mut ServeClient, slowest: usize) -> anyhow::Result<TraceReply> {
    client.call(Op::Traces { slowest })?.into_traces()
}

fn health(client: &mut ServeClient) -> anyhow::Result<HealthReply> {
    client.call(Op::Health)?.into_health()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ecokernel_serve_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A quick daemon: small searches, small pool, temp store + socket.
fn spawn_daemon(tag: &str, tune: impl FnOnce(&mut SearchConfig)) -> (DaemonHandle, PathBuf) {
    let dir = tmp_dir(tag);
    let mut search = SearchConfig {
        gpu: GpuArch::A100,
        mode: SearchMode::EnergyAware,
        population: 24,
        m_latency_keep: 6,
        rounds: 3,
        patience: 0,
        seed: 7,
        ..Default::default()
    };
    search.serve.n_workers = 1;
    search.serve.n_shards = 4;
    tune(&mut search);
    let handle = Daemon::spawn(
        DaemonConfig {
            addr: ServeAddr::Unix(dir.join("ecokernel.sock")),
            store_dir: dir.clone(),
            search,
        },
        None,
    )
    .unwrap();
    (handle, dir)
}

fn stop(handle: DaemonHandle, dir: &Path) {
    let mut client = ServeClient::connect(&handle.addr).unwrap();
    client.shutdown().unwrap();
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(dir);
}

/// The acceptance e2e: two identical `get_kernel` requests — the first
/// is a miss that triggers a background search, the second is served
/// from the sharded store with 0 NVML measurements.
#[test]
fn miss_then_background_search_then_hit_with_zero_measurements() {
    let (handle, dir) = spawn_daemon("hitmiss", |_| {});
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    let first = get_kernel(&mut client, suites::MM1, None, None).unwrap();
    assert!(!first.hit, "a fresh store cannot hit");
    assert!(first.enqueued, "first miss enqueues the real search");
    assert_eq!(first.source, ServeSource::Fallback, "empty store has no neighbor to guess from");
    assert!(first.queue_depth >= 1);

    // Wait for the background search to be written back.
    let drained = client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert_eq!(drained.n_searches_done, 1);
    let paid_after_search = drained.measurements_paid;
    assert!(paid_after_search > 0, "the background search pays real measurements");

    let second = get_kernel(&mut client, suites::MM1, None, None).unwrap();
    assert!(second.hit, "identical request must now hit the store");
    assert_eq!(second.source, ServeSource::Store);
    assert!(!second.enqueued, "hits never re-search");
    assert!(second.energy_j > 0.0 && second.latency_s > 0.0, "measured metrics served");

    // The hit itself paid nothing: the daemon's measurement ledger is
    // unchanged, and no new search ran.
    let s = stats(&mut client).unwrap();
    assert_eq!(s.measurements_paid, paid_after_search, "a hit costs 0 NVML measurements");
    assert_eq!(s.n_searches_done, 1);
    assert_eq!(s.n_hits, 1);
    assert_eq!(s.n_misses, 1);

    // A neighboring shape misses but gets a warm guess from the cached
    // MM1 record instead of the blind fallback.
    let neighbor = get_kernel(&mut client, suites::MM2, None, None).unwrap();
    assert!(!neighbor.hit);
    assert_eq!(neighbor.source, ServeSource::WarmGuess);
    assert!(neighbor.energy_j > 0.0, "warm guesses carry MAC-rescaled estimates");
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();

    stop(handle, &dir);
}

/// The search-free static tier (ISSUE 9 acceptance): a never-seen key
/// on a fresh store is answered from the static ranking with
/// closed-form estimates and ZERO NVML measurements; duplicates of the
/// static-tier miss coalesce into the one background search; once it
/// lands, the same request upgrades to the exact tier.
#[test]
fn never_seen_key_is_served_static_then_exact() {
    let (handle, dir) = spawn_daemon("statictier", |s| {
        // Slow search: the in-flight window below is long enough to
        // read stats and send a duplicate before any write-back lands.
        s.population = 256;
        s.m_latency_keep = 16;
        s.rounds = 12;
    });
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    let first = get_kernel(&mut client, suites::CONV2, None, None).unwrap();
    assert!(!first.hit, "fresh store cannot hit");
    assert_eq!(first.source, ServeSource::Fallback, "no neighbor on an empty store");
    assert_eq!(first.tier, ServeTier::Static, "the fallback is the static tier");
    assert!(first.enqueued, "the real search still runs in the background");
    // The static tier carries real closed-form estimates, not 0.0
    // "unknown" — and exactly the analyzer's numbers for exactly the
    // analyzer's best-ranked schedule.
    let spec = GpuArch::A100.spec();
    let (best, prof) = ecokernel::analysis::best_static(suites::CONV2, &spec);
    assert_eq!(first.schedule, best, "the best statically-ranked schedule is served");
    assert_eq!(first.energy_j, prof.static_energy_j);
    assert_eq!(first.latency_s, prof.static_latency_s);
    assert_eq!(first.avg_power_w, prof.static_avg_power_w);
    assert!(first.energy_j > 0.0 && first.latency_s > 0.0 && first.avg_power_w > 0.0);

    // Zero measurements paid while the reply is already in hand (the
    // search is still in flight), and the tier counter saw the miss.
    let s = stats(&mut client).unwrap();
    assert_eq!(s.measurements_paid, 0, "the static tier pays 0 NVML measurements");
    assert_eq!(s.n_static_tier, 1);
    assert_eq!(s.n_searches_done, 0, "search still in flight");

    // A duplicate of the static-tier miss — raw frame, so the wire
    // bytes are pinned too — coalesces instead of re-enqueueing.
    let raw = client
        .roundtrip_raw(r#"{"v":1,"op":"get_kernel","id":"dup","workload":"CONV2"}"#)
        .unwrap();
    assert!(raw.contains(r#""tier":"static""#), "{raw}");
    assert!(raw.contains(r#""source":"fallback""#), "{raw}");
    assert!(raw.contains(r#""enqueued":false"#), "duplicate coalesces: {raw}");
    let s = stats(&mut client).unwrap();
    assert_eq!(s.n_enqueued, 1, "one search for both static-tier misses");
    assert_eq!(s.n_static_tier, 2);

    // The background search lands; the same key is now the exact tier
    // with measured metrics, and no further static-tier replies.
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    let second = get_kernel(&mut client, suites::CONV2, None, None).unwrap();
    assert!(second.hit);
    assert_eq!(second.tier, ServeTier::Exact);
    assert_eq!(second.source, ServeSource::Store);
    let s = stats(&mut client).unwrap();
    assert_eq!(s.n_searches_done, 1);
    assert!(s.measurements_paid > 0, "the background search paid the measurements");
    assert_eq!(s.n_static_tier, 2, "the exact hit added no static-tier reply");

    stop(handle, &dir);
}

/// Duplicate in-flight requests coalesce into one background search.
#[test]
fn duplicate_misses_enqueue_only_one_search() {
    let (handle, dir) = spawn_daemon("dup", |_| {});
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    let a = get_kernel(&mut client, suites::MV3, None, None).unwrap();
    let b = get_kernel(&mut client, suites::MV3, None, None).unwrap();
    assert!(a.enqueued, "first miss enqueues");
    assert!(!b.enqueued, "in-flight duplicate coalesces");
    let s = client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert_eq!(s.n_enqueued, 1);
    assert_eq!(s.n_searches_done, 1);
    assert_eq!(s.n_misses, 2);

    stop(handle, &dir);
}

/// Per-GPU quota: after overflow the least-recently-served key is
/// evicted, while retained keys keep hitting.
#[test]
fn per_gpu_quota_evicts_lru_but_retained_keys_still_hit() {
    // Each quick search stores 1 record per key; quota 2 on the A100.
    let (handle, dir) = spawn_daemon("evict", |s| s.serve.per_gpu_quota = 2);
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    // Fill: MM1 then MV3, each searched and written back.
    get_kernel(&mut client, suites::MM1, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    get_kernel(&mut client, suites::MV3, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();

    // Serve MM1 again: MV3 is now the least-recently-served key.
    assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().hit);

    // CONV2 overflows the quota: its write-back evicts MV3.
    get_kernel(&mut client, suites::CONV2, None, None).unwrap();
    let s = client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert!(s.n_evicted_records >= 1, "overflow evicted something");
    assert_eq!(s.n_records, 2, "store holds exactly the quota");

    // Retained keys are unaffected — both still exact hits...
    assert!(
        get_kernel(&mut client, suites::MM1, None, None).unwrap().hit,
        "recently-served retained"
    );
    assert!(get_kernel(&mut client, suites::CONV2, None, None).unwrap().hit, "fresh key retained");
    // ...while the evicted key is a miss again.
    let evicted = get_kernel(&mut client, suites::MV3, None, None).unwrap();
    assert!(!evicted.hit, "LRU victim was evicted");
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();

    stop(handle, &dir);
}

/// The hot path is not serialized behind miss handling: while a slow
/// background search is in flight for one key, exact hits for another
/// key — on a separate connection — keep completing.
#[test]
fn hits_are_served_while_a_miss_search_is_in_flight() {
    let (handle, dir) = spawn_daemon("parallel", |s| {
        // Slow searches: each stays in flight long enough for the hit
        // burst below to run against a busy daemon.
        s.population = 256;
        s.m_latency_keep = 16;
        s.rounds = 12;
    });
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    // Fill MM1, then start a second slow search (MM2) and leave it
    // running.
    get_kernel(&mut client, suites::MM1, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    let miss = get_kernel(&mut client, suites::MM2, None, None).unwrap();
    assert!(!miss.hit && miss.enqueued);

    // Hits on a second connection land while the MM2 search runs.
    let mut other = ServeClient::connect(&handle.addr).unwrap();
    for _ in 0..5 {
        assert!(get_kernel(&mut other, suites::MM1, None, None).unwrap().hit);
    }
    let stats = stats(&mut other).unwrap();
    assert!(stats.n_hits >= 5, "hits were served mid-search: {}", stats.n_hits);

    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    stop(handle, &dir);
}

/// Protocol errors over a real socket: malformed frames, version
/// mismatch, unknown workloads — each maps to its error code and the
/// connection survives.
#[test]
fn protocol_errors_over_the_socket() {
    let (handle, dir) = spawn_daemon("proto", |_| {});
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    let cases = [
        ("{definitely not json", error_code::BAD_REQUEST),
        (r#"{"v":99,"op":"stats","id":"x"}"#, error_code::VERSION_MISMATCH),
        (r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM99"}"#, error_code::UNKNOWN_WORKLOAD),
        (r#"{"v":1,"op":"frobnicate","id":"x"}"#, error_code::BAD_REQUEST),
        // A present-but-unparseable trace id is refused loudly instead
        // of silently minting a fresh id (orphaning the correlation).
        (
            r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM1","trace":"nothex!"}"#,
            error_code::BAD_REQUEST,
        ),
    ];
    for (line, expect) in cases {
        let reply = client.roundtrip_raw(line).unwrap();
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false), "{line}");
        let code = v.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str());
        assert_eq!(code, Some(expect), "{line}");
        if line.contains("nothex!") {
            let msg = v
                .get("error")
                .and_then(|e| e.get("message"))
                .and_then(|m| m.as_str())
                .unwrap_or_default();
            assert!(msg.contains("trace"), "the error names the bad field: {reply}");
        }
    }
    // The connection still serves valid requests afterwards.
    assert!(stats(&mut client).is_ok());

    stop(handle, &dir);
}

/// Batch frames over a real socket: a malformed entry maps to an error
/// frame at ITS position while siblings are served, and frame-level
/// batch errors reject the whole frame.
#[test]
fn batch_errors_are_positional_over_the_socket() {
    let (handle, dir) = spawn_daemon("batcherr", |_| {});
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    // Warm MM1 so position 0 is an exact hit.
    get_kernel(&mut client, suites::MM1, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();

    let frame = r#"{"v":1,"op":"batch","id":"bx","requests":[
        {"workload":"MM1"},{"workload":"MM99"},{"workload":"MM2","gpu":"tpu"}]}"#
        .replace('\n', "");
    let reply = client.roundtrip_raw(&frame).unwrap();
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
    assert_eq!(v.get("id").and_then(|x| x.as_str()), Some("bx"));
    let replies = v.get("replies").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(replies.len(), 3, "one reply frame per request: {reply}");
    assert_eq!(
        replies[0].get("result").and_then(|x| x.as_str()),
        Some("hit"),
        "the good entry is served despite bad siblings"
    );
    let code = |i: usize| {
        replies[i].get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str())
    };
    assert_eq!(code(1), Some(error_code::UNKNOWN_WORKLOAD));
    assert_eq!(code(2), Some(error_code::BAD_REQUEST));
    assert_eq!(
        replies[2].get("id").and_then(|x| x.as_str()),
        Some("bx.2"),
        "positional default id echoed on the error frame"
    );

    // Frame-level errors reject the whole batch with one error frame.
    for bad in [
        r#"{"v":1,"op":"batch","id":"b0","requests":[]}"#,
        r#"{"v":1,"op":"batch","id":"b0"}"#,
    ] {
        let reply = client.roundtrip_raw(bad).unwrap();
        let v = Json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false), "{bad}");
    }

    // Batch counters: the mixed frame above counted once, with three
    // requests riding in it (error positions included in the frame's
    // request count, not in hit/miss metrics).
    let s = stats(&mut client).unwrap();
    assert_eq!(s.n_batch_frames, 1);
    assert_eq!(s.n_batch_requests, 3);

    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    stop(handle, &dir);
}

/// Driver-level serving metrics: hit rate, reply-time percentiles on
/// the simulated clock, and the served-vs-searched split.
#[test]
fn serving_metrics_separate_served_from_searched() {
    let (handle, dir) = spawn_daemon("metrics", |_| {});
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    // 1 miss + search, then 4 hits.
    get_kernel(&mut client, suites::MM1, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    for _ in 0..4 {
        assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().hit);
    }
    let s = stats(&mut client).unwrap();
    assert_eq!(s.n_requests, 5);
    assert_eq!((s.n_hits, s.n_misses), (4, 1));
    assert!((s.hit_rate - 0.8).abs() < 1e-9);
    assert_eq!(s.n_searches_done, 1, "5 requests, 1 search: amortization in action");
    assert_eq!(s.queue_depth, 0);
    // Simulated reply times: hits dominate p50, the miss (neighbor
    // scan) dominates p99.
    assert!(s.p50_reply_s > 0.0);
    assert!(s.p99_reply_s >= s.p50_reply_s);
    // Operational identity (ISSUE 8): a live daemon reports a real
    // uptime and names the build serving the socket.
    assert!(s.uptime_s > 0.0, "{}", s.uptime_s);
    assert!(s.build_info.starts_with("ecokernel "), "{}", s.build_info);

    stop(handle, &dir);
}

/// The `metrics` op end to end: per-stage wall-clock histograms with
/// exact counts, both reply clocks, counters matching `stats`, and
/// Prometheus exposition — all from a live daemon.
#[test]
fn metrics_op_reports_stage_histograms() {
    let (handle, dir) = spawn_daemon("metricsop", |_| {});
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    // 1 miss (searched + drained) + 4 exact hits.
    get_kernel(&mut client, suites::MM1, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    for _ in 0..4 {
        assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().hit);
    }

    let m = metrics(&mut client).unwrap();
    assert_eq!(m.counter("n_requests"), 5);
    assert_eq!(m.counter("n_hits"), 4);
    assert_eq!(m.counter("n_misses"), 1);
    assert_eq!(m.counter("n_searches_done"), 1);

    // Both reply clocks saw every request; wall-clock values are real
    // durations on this machine.
    assert_eq!(m.reply_sim_s.count(), 5);
    assert_eq!(m.reply_wall_s.count(), 5);
    assert!(m.reply_wall_s.min() > 0.0);
    assert!(m.reply_wall_s.quantile(99.0) >= m.reply_wall_s.quantile(50.0));

    // Stage counts are exact: every request parses and reads a shard;
    // only the miss pays snapshot lookup, claim I/O, and enqueue. The
    // stats polls above are untraced frames, so they pollute nothing.
    let stage = |name: &str| m.stages.get(name).unwrap();
    assert_eq!(stage("parse").count(), 5);
    assert_eq!(stage("shard_read").count(), 5);
    assert_eq!(stage("snapshot_lookup").count(), 1);
    assert_eq!(stage("claim_io").count(), 1);
    assert_eq!(stage("enqueue").count(), 1);
    // Reply writes are recorded post-flush, one per traced frame, and
    // sequential handling on this connection means all 5 landed before
    // the `metrics` frame was parsed.
    assert_eq!(stage("reply_write").count(), 5);
    assert!(stage("reply_write").min() > 0.0);

    // Cost-model accuracy histograms rode along: the drained search's
    // rounds landed per regime, keyed `family/regime`.
    assert!(!m.model.is_empty(), "model telemetry after one search");
    assert!(
        m.model.keys().any(|k| k.starts_with("model_dynamic_k/")),
        "{:?}",
        m.model.keys().collect::<Vec<_>>()
    );

    // The energy ledger rode along (ISSUE 8): the search debited real
    // measurement joules, and all 4 hits were credited to the a100/mm
    // cell — attributed, because the fresh record carries a baseline.
    let (gpu, mm) = (ledger_gpu_index("a100").unwrap(), ledger_family_index("mm"));
    assert_eq!(m.energy.n_hits(gpu, mm), 4);
    assert_eq!(m.energy.n_searches(gpu, mm), 1);
    assert!(m.energy.paid_j(gpu, mm) > 0.0, "{}", m.energy.paid_j(gpu, mm));
    assert!(m.energy.saved_j(gpu, mm) >= 0.0);
    assert_eq!(m.energy.total_unattributed(), 0);

    // The same snapshot as Prometheus text.
    let prom = m.to_prometheus();
    assert!(prom.contains("# TYPE ecokernel_requests_total counter"), "{prom}");
    assert!(prom.contains("ecokernel_requests_total 5"), "{prom}");
    assert!(prom.contains("ecokernel_hits_total 4"), "{prom}");
    assert!(prom.contains("ecokernel_reply_wall_seconds_count 5"), "{prom}");
    assert!(prom.contains("ecokernel_stage_seconds_count{stage=\"parse\"} 5"), "{prom}");
    assert!(prom.contains("# TYPE ecokernel_model_dynamic_k histogram"), "{prom}");
    assert!(prom.contains("regime="), "{prom}");
    assert!(
        prom.contains("ecokernel_energy_saved_joules_total{gpu=\"a100\",family=\"mm\"}"),
        "{prom}"
    );

    stop(handle, &dir);
}

/// The `health` op end to end on one daemon: the raw wire shape is
/// versioned and carries every `[slo]` target, and the typed client
/// agrees with it.
#[test]
fn health_op_reports_slo_targets_over_the_socket() {
    let (handle, dir) = spawn_daemon("healthop", |_| {});
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    get_kernel(&mut client, suites::MM1, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().hit);

    // Raw frame: versioned, ok, one entry per [slo] target.
    let reply = client.roundtrip_raw(r#"{"v":1,"op":"health","id":"h1"}"#).unwrap();
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true), "{reply}");
    assert_eq!(v.get("op").and_then(|x| x.as_str()), Some("health"), "{reply}");
    assert_eq!(
        v.get("health_v").and_then(|x| x.as_f64()),
        Some(HEALTH_VERSION as f64),
        "{reply}"
    );
    let targets = v.get("targets").and_then(|t| t.as_arr()).unwrap();
    let names: Vec<&str> =
        targets.iter().filter_map(|t| t.get("name").and_then(|n| n.as_str())).collect();
    assert_eq!(names, ["p99_reply_wall_s", "hit_rate", "relerr_steady", "backlog"], "{reply}");
    assert!(v.get("drift").and_then(|d| d.get("budget")).is_some(), "{reply}");

    // Typed client: a barely-used daemon under default [slo] targets
    // is healthy (windows below min_window never breach), each target
    // says WHY it holds, and the reply parses losslessly.
    let h = health(&mut client).unwrap();
    assert_eq!(h.status, HealthStatus::Ok, "{h:?}");
    assert_eq!(h.targets.len(), 4);
    assert!(h.targets.iter().all(|t| !t.reason.is_empty()), "{h:?}");
    assert!(!h.drift.drifting, "default ceiling (0.35) holds: {:?}", h.drift);
    assert_eq!(h.drift.n_drift_researches, 0);

    stop(handle, &dir);
}

/// The `trace` op end to end on one daemon: a miss opens exactly one
/// trace; once drained it is complete, carries the hot-path stages and
/// the search/write-back story, and hits never add traces.
#[test]
fn trace_op_returns_the_completed_miss_chain() {
    let (handle, dir) = spawn_daemon("traceop", |_| {});
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().enqueued);
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    for _ in 0..3 {
        assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().hit);
    }

    // The trace closes moments after the drain (the write-back's
    // bookkeeping finishes outside the lock the drain poll reads).
    let deadline = std::time::Instant::now() + DRAIN_TIMEOUT;
    let t = loop {
        let tr = traces(&mut client, 0).unwrap();
        if let Some(t) = tr.traces.first().filter(|t| t.complete) {
            assert_eq!(tr.traces.len(), 1, "the 3 hits added no traces: {tr:?}");
            break t.clone();
        }
        assert!(std::time::Instant::now() < deadline, "trace never completed");
        std::thread::sleep(std::time::Duration::from_millis(50));
    };
    assert!(!t.error && !t.remote);
    assert!(t.total_s > 0.0);
    assert!(t.start_unix_s > 0.0);
    let names: Vec<&str> = t.spans.iter().map(|s| s.name.as_str()).collect();
    for expected in ["parse", "shard_read", "enqueue", "reply_write", "writeback"] {
        assert!(names.contains(&expected), "missing '{expected}' in {names:?}");
    }
    // `--slowest 1` caps the reply; the lone trace survives the cap.
    assert_eq!(traces(&mut client, 1).unwrap().traces.len(), 1);

    stop(handle, &dir);
}

/// Per-request gpu/mode overrides are separate serve keys.
#[test]
fn gpu_and_mode_are_part_of_the_serve_key() {
    let (handle, dir) = spawn_daemon("keys", |_| {});
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    get_kernel(&mut client, suites::MM1, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().hit);

    // Same workload on another GPU is its own key: a miss.
    let other_gpu = get_kernel(&mut client, suites::MM1, Some(GpuArch::V100), None).unwrap();
    assert!(!other_gpu.hit);
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert!(get_kernel(&mut client, suites::MM1, Some(GpuArch::V100), None).unwrap().hit);

    stop(handle, &dir);
}
