//! Integration: AOT artifacts -> PJRT load/compile/execute -> numerics
//! vs Rust-side f64 oracles. Requires `make artifacts` (the suite skips
//! gracefully when artifacts are absent, e.g. in a fresh checkout).

use ecokernel::runtime::{ArtifactRegistry, LoadedKernel};
use ecokernel::util::Rng;

fn registry() -> Option<ArtifactRegistry> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactRegistry::open(&dir) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping runtime_e2e: {e:#}");
            None
        }
    }
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * 0.1).collect()
}

#[test]
fn mm_artifact_matches_f64_oracle() {
    let Some(reg) = registry() else { return };
    let meta = reg.get("mm_b1_m512_n512_k512", "bm64_bn64_bk16").expect("palette member");
    let k = reg.load(meta).expect("compile");
    let mut rng = Rng::seed_from_u64(1);
    let x = rand_vec(&mut rng, 512 * 512);
    let w = rand_vec(&mut rng, 512 * 512);
    let shape = [512usize, 512];
    let out = k.run_f32(&[(&x, &shape), (&w, &shape)]).expect("execute");
    assert_eq!(out.len(), 512 * 512);
    for _ in 0..50 {
        let i = rng.gen_range(0, 512);
        let j = rng.gen_range(0, 512);
        let mut acc = 0.0f64;
        for kk in 0..512 {
            acc += x[i * 512 + kk] as f64 * w[kk * 512 + j] as f64;
        }
        let got = out[i * 512 + j] as f64;
        assert!((got - acc).abs() < 1e-3, "({i},{j}): {got} vs {acc}");
    }
}

#[test]
fn mv_artifact_matches_f64_oracle() {
    let Some(reg) = registry() else { return };
    let meta = reg.get("mv_b1_n4096_k1024", "bm1_bn128_bk128").expect("palette member");
    let k = reg.load(meta).expect("compile");
    let mut rng = Rng::seed_from_u64(2);
    let w = rand_vec(&mut rng, 4096 * 1024);
    let x = rand_vec(&mut rng, 1024);
    let out = k
        .run_f32(&[(&w, &[4096usize, 1024]), (&x, &[1024usize])])
        .expect("execute");
    assert_eq!(out.len(), 4096);
    for _ in 0..50 {
        let i = rng.gen_range(0, 4096);
        let mut acc = 0.0f64;
        for kk in 0..1024 {
            acc += w[i * 1024 + kk] as f64 * x[kk] as f64;
        }
        assert!((out[i] as f64 - acc).abs() < 1e-3);
    }
}

#[test]
fn all_mm_variants_agree_with_each_other() {
    // Every block geometry computes the SAME function — variants must
    // agree bitwise-closely on identical inputs.
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(3);
    let x = rand_vec(&mut rng, 512 * 512);
    let w = rand_vec(&mut rng, 512 * 512);
    let shape = [512usize, 512];
    let variants = reg.variants("mm_b1_m512_n512_k512");
    assert!(variants.len() >= 10);
    let mut reference: Option<Vec<f32>> = None;
    // Cap compile cost: check 6 spread-out variants.
    for meta in variants.iter().step_by((variants.len() / 6).max(1)) {
        let k = reg.load(meta).expect("compile");
        let out = k.run_f32(&[(&x, &shape), (&w, &shape)]).expect("execute");
        match &reference {
            None => reference = Some(out),
            Some(r) => {
                let max_diff = r
                    .iter()
                    .zip(&out)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(max_diff < 1e-3, "{}: diverges by {max_diff}", meta.name());
            }
        }
    }
}

#[test]
fn wrong_input_shapes_are_rejected() {
    let Some(reg) = registry() else { return };
    let meta = reg.get("mm_b1_m512_n512_k512", "bm64_bn64_bk16").expect("member");
    let k = reg.load(meta).expect("compile");
    let tiny = vec![1.0f32; 16];
    let shape = [4usize, 4];
    assert!(k.run_f32(&[(&tiny, &shape), (&tiny, &shape)]).is_err());
    let x = vec![1.0f32; 512 * 512];
    let s = [512usize, 512];
    assert!(k.run_f32(&[(&x, &s)]).is_err(), "arity check");
}

#[test]
fn nearest_mapping_always_resolves_for_search_winners() {
    let Some(reg) = registry() else { return };
    use ecokernel::config::{GpuArch, SearchMode};
    use ecokernel::schedule::space::ScheduleSpace;
    let spec = GpuArch::A100.spec();
    let space = ScheduleSpace::new(ecokernel::workload::suites::MM1, &spec);
    let mut rng = Rng::seed_from_u64(4);
    let _ = SearchMode::EnergyAware;
    for s in space.sample_n(&mut rng, 100) {
        let m = reg.nearest("mm_b1_m512_n512_k512", &s);
        assert!(m.is_some(), "no artifact for {s}");
    }
    let _ = LoadedKernel::load; // keep the symbol referenced
}
