//! Golden-file pins for the static analyzer (ISSUE 9, satellite c).
//!
//! The `analyze` CLI subcommand and the serve daemon's static tier are
//! only trustworthy if the analyzer is **bytewise** deterministic: the
//! same (workload, schedule, spec) must serialize to the same JSON on
//! every run and every host, or fleet daemons would disagree on the
//! statically-best schedule and the CI double-run diff would flap.
//!
//! This suite pins the full `analyze`-shaped document — the exact
//! object `ecokernel analyze --workload W --gpu G` prints — for one
//! GEMM (MM1), one im2col conv (CONV2), and one matrix-vector (MV3)
//! workload on every GPU spec. Goldens live in `tests/golden/` and are
//! blessed on first run (missing file => write + note on stderr), so
//! regenerating after an *intentional* model change is `rm` + two test
//! runs — and CI runs this test binary twice back to back, so even a
//! fresh checkout gets a real bytes-stable-across-runs check.

use ecokernel::analysis::{self, StaticProfile};
use ecokernel::config::GpuArch;
use ecokernel::store::record::schedule_to_json;
use ecokernel::util::Json;
use ecokernel::workload::{suites, Workload};
use std::path::PathBuf;

/// The three workload families pinned per spec: blocked GEMM, im2col
/// convolution, and the memory-bound matrix-vector shape.
const PINNED: [(&str, Workload); 3] =
    [("mm1", suites::MM1), ("conv2", suites::CONV2), ("mv3", suites::MV3)];

fn golden_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"))
}

/// Build the same document `cmd_analyze` prints (top=1). Kept in sync
/// by `analyze_document_shape_is_pinned` below: if the CLI shape
/// changes, the hardcoded key sets there must change with it.
fn analyze_doc(workload: Workload, gpu: GpuArch) -> Json {
    let spec = gpu.spec();
    let ranked = analysis::rank_static(workload, &spec, 1);
    let entries = ranked.iter().map(|(s, p)| {
        Json::obj(vec![
            ("schedule", schedule_to_json(s)),
            ("variant_id", Json::str(s.variant_id())),
            ("profile", p.to_json()),
        ])
    });
    Json::obj(vec![
        ("workload", Json::str(workload.id())),
        ("gpu", Json::str(gpu.name())),
        ("n_ranked", Json::num(ranked.len() as f64)),
        ("ranked", Json::arr(entries)),
    ])
}

/// Every key the profile object may carry, alphabetical (Json::Obj is a
/// BTreeMap, so serialization order == this order). A new StaticProfile
/// field must be added here *and* a fresh golden blessed.
const PROFILE_KEYS: [&str; 16] = [
    "active_sm_frac",
    "arithmetic_intensity",
    "dram_bytes",
    "flops",
    "int_ops",
    "l2_bytes",
    "occupancy",
    "predicted_stall_frac",
    "reg_bytes",
    "shared_bytes",
    "static_avg_power_w",
    "static_energy_j",
    "static_latency_s",
    "tail_efficiency",
    "tile_reuse_factor",
    "waves",
];

#[test]
fn analyze_document_shape_is_pinned() {
    let doc = analyze_doc(suites::MM1, GpuArch::A100);
    let Json::Obj(top) = &doc else { panic!("analyze doc must be an object") };
    let top_keys: Vec<&str> = top.keys().map(|k| k.as_str()).collect();
    assert_eq!(
        top_keys,
        ["gpu", "n_ranked", "ranked", "workload"],
        "analyze top-level key set changed — update this pin, the CI \
         analyze-smoke validator, and re-bless the goldens together"
    );
    let ranked = doc.get("ranked").and_then(Json::as_arr).expect("ranked array");
    assert_eq!(ranked.len(), 1);
    let Json::Obj(entry) = &ranked[0] else { panic!("ranked entry must be an object") };
    let entry_keys: Vec<&str> = entry.keys().map(|k| k.as_str()).collect();
    assert_eq!(entry_keys, ["profile", "schedule", "variant_id"]);
    let Some(Json::Obj(profile)) = entry.get("profile") else {
        panic!("profile must be an object")
    };
    let profile_keys: Vec<&str> = profile.keys().map(|k| k.as_str()).collect();
    assert_eq!(
        profile_keys, PROFILE_KEYS,
        "StaticProfile::to_json key set changed — update PROFILE_KEYS \
         and re-bless the goldens"
    );
}

/// The golden pin proper: for each (workload family, GPU spec) pair the
/// serialized analyze document must match `tests/golden/` byte for
/// byte. Each document is also computed twice in-process and compared,
/// so a nondeterministic analyzer fails even on a bless run.
#[test]
fn analyze_output_matches_goldens() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).expect("create golden dir");
    let mut blessed = Vec::new();
    for gpu in GpuArch::ALL {
        for (tag, workload) in PINNED {
            let once = analyze_doc(workload, gpu).to_string();
            let twice = analyze_doc(workload, gpu).to_string();
            assert_eq!(once, twice, "{tag}/{}: analyzer not bytewise deterministic", gpu.name());
            // Parse round-trip: the golden must stay machine-readable
            // (the CI analyze-smoke step validates it with python).
            Json::parse(&once).expect("analyze doc must parse as JSON");
            let path = dir.join(format!("analyze_{tag}_{}.json", gpu.name()));
            match std::fs::read_to_string(&path) {
                Ok(want) => assert_eq!(
                    once,
                    want.trim_end(),
                    "{tag}/{}: analyze output drifted from {} — if the \
                     static model changed intentionally, delete the \
                     golden and re-run to bless",
                    gpu.name(),
                    path.display()
                ),
                Err(_) => {
                    let mut body = once;
                    body.push('\n');
                    std::fs::write(&path, body).expect("bless golden");
                    blessed.push(path.display().to_string());
                }
            }
        }
    }
    if !blessed.is_empty() {
        // A bless run still checked in-process determinism above; the
        // cross-run byte pin needs a second invocation (CI does this).
        eprintln!(
            "blessed {} missing golden(s) — run again to verify against them:\n  {}",
            blessed.len(),
            blessed.join("\n  ")
        );
    }
}

/// Cross-spec sanity on the pinned profiles: best-static energy is
/// positive and the memory-bound MV shape is predicted more
/// stall-bound than the compute-rich GEMM on every spec.
#[test]
fn pinned_profiles_are_physically_ordered() {
    for gpu in GpuArch::ALL {
        let spec = gpu.spec();
        let profile = |w: Workload| -> StaticProfile { analysis::best_static(w, &spec).1 };
        let mm = profile(suites::MM1);
        let mv = profile(suites::MV3);
        assert!(mm.static_energy_j > 0.0 && mv.static_energy_j > 0.0, "{}", gpu.name());
        assert!(
            mv.predicted_stall_frac > mm.predicted_stall_frac,
            "{}: MV ({}) should be more stall-bound than GEMM ({})",
            gpu.name(),
            mv.predicted_stall_frac,
            mm.predicted_stall_frac
        );
        assert!(
            mm.arithmetic_intensity > mv.arithmetic_intensity,
            "{}: GEMM should have higher arithmetic intensity",
            gpu.name()
        );
    }
}
