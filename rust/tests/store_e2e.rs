//! Integration: the persistent tuning store end to end — exact-hit
//! replay, warm-start transfer across neighboring shapes, and
//! reproducibility of warm-started searches (the ISSUE 1 acceptance
//! criteria).

use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
use ecokernel::search::run_search;
use ecokernel::store::TuningStore;
use ecokernel::workload::suites;
use std::path::PathBuf;

fn tmp_store(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ecokernel_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cfg(seed: u64, store_dir: Option<&PathBuf>) -> SearchConfig {
    let mut c = SearchConfig {
        gpu: GpuArch::A100,
        mode: SearchMode::EnergyAware,
        population: 48,
        m_latency_keep: 12,
        rounds: 6,
        patience: 0,
        seed,
        ..Default::default()
    };
    c.store.dir = store_dir.map(|d| d.to_string_lossy().into_owned());
    c
}

#[test]
fn second_identical_search_is_an_exact_cache_hit() {
    // `ecokernel search --workload MM1 --store DIR` twice: the second
    // run must cost zero measurements and return the identical kernel.
    let dir = tmp_store("exact_hit");
    let c = cfg(3, Some(&dir));

    let first = run_search(suites::MM1, &c);
    assert!(first.n_energy_measurements() > 0, "first run searches for real");
    assert!(first.clock.total_s > 0.0);

    let second = run_search(suites::MM1, &c);
    assert_eq!(second.n_energy_measurements(), 0, "exact hit measures nothing");
    assert_eq!(second.clock.total_s, 0.0, "exact hit costs zero simulated time");
    assert_eq!(second.best.schedule, first.best.schedule, "identical best schedule");
    assert!((second.best.energy_j - first.best.energy_j).abs() < 1e-12);

    // A different seed is a different fingerprint: no false hit.
    let other = run_search(suites::MM1, &cfg(4, Some(&dir)));
    assert!(other.n_energy_measurements() > 0, "different config must re-search");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn transfer_to_neighbor_shape_saves_measurements_at_equal_energy() {
    // Seed the store with MM1, then search the neighboring MM2 shape:
    // warm-start transfer must use measurably fewer NVML energy
    // measurements than the cold run, at equal-or-better final energy.
    let dir = tmp_store("transfer");
    let seed_run = run_search(suites::MM1, &cfg(5, Some(&dir)));
    assert!(seed_run.n_energy_measurements() > 0);

    let cold = run_search(suites::MM2, &cfg(6, None));

    // `--no-transfer` (checked before MM2 is cached) reverts to the
    // cold trajectory exactly.
    let mut no_transfer = cfg(6, Some(&dir));
    no_transfer.store.transfer = false;
    no_transfer.store.write_back = false;
    let isolated = run_search(suites::MM2, &no_transfer);
    assert_eq!(isolated.best.schedule, cold.best.schedule);
    assert_eq!(isolated.n_energy_measurements(), cold.n_energy_measurements());

    let warm = run_search(suites::MM2, &cfg(6, Some(&dir)));
    assert!(
        warm.n_energy_measurements() < cold.n_energy_measurements(),
        "warm {} !< cold {} energy measurements",
        warm.n_energy_measurements(),
        cold.n_energy_measurements()
    );
    assert!(
        warm.best.energy_j <= cold.best.energy_j * 1.05,
        "warm energy regressed: {} mJ vs cold {} mJ",
        warm.best.energy_j * 1e3,
        cold.best.energy_j * 1e3
    );
    // Transfer must not bypass final measurement: the winner is real.
    assert!(warm.best.energy_measured);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_started_search_is_deterministic() {
    let dir = tmp_store("determinism");
    let _ = run_search(suites::MV3, &cfg(7, Some(&dir)));

    // write_back off so the first warm run does not turn the second
    // into an exact hit — both must perform the same warm search.
    let mut warm_cfg = cfg(8, Some(&dir));
    warm_cfg.store.write_back = false;
    let a = run_search(suites::MV4, &warm_cfg);
    let b = run_search(suites::MV4, &warm_cfg);
    assert_eq!(a.best.schedule, b.best.schedule);
    assert_eq!(a.k_trace, b.k_trace);
    assert_eq!(a.n_energy_measurements(), b.n_energy_measurements());
    assert_eq!(a.clock.total_s, b.clock.total_s);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_survives_reopen_and_serves_across_processes_shape() {
    // Simulates the two-invocation CLI flow: process A searches and
    // exits; process B reopens the directory and gets the hit.
    let dir = tmp_store("reopen");
    let c = cfg(9, Some(&dir));
    let first = run_search(suites::CONV2, &c);

    let store = TuningStore::open(&dir).expect("reopen");
    assert_eq!(store.len(), 1);
    let rec = store.exact_hit(suites::CONV2, &c).expect("hit after reopen");
    assert_eq!(rec.best.schedule, first.best.schedule);
    assert_eq!(rec.n_energy_measurements, first.n_energy_measurements());
    let _ = std::fs::remove_dir_all(&dir);
}
