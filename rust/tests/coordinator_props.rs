//! Property-based tests on coordinator and search invariants (routing,
//! batching, state): hand-rolled property harness over seeded cases.

use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
use ecokernel::coordinator::{SearchJob, WorkerPool};
use ecokernel::schedule::space::ScheduleSpace;
use ecokernel::search::{select_final, EvaluatedKernel, KController, FINAL_LATENCY_TOL};
use ecokernel::util::Rng;
use ecokernel::workload::suites;

fn forall(seed: u64, n: usize, mut prop: impl FnMut(&mut Rng, usize)) {
    let mut rng = Rng::seed_from_u64(seed);
    for case in 0..n {
        let mut case_rng = rng.fork(case as u64);
        prop(&mut case_rng, case);
    }
}

#[test]
fn prop_k_controller_state_stays_legal_under_any_snr_sequence() {
    forall(1, 50, |rng, case| {
        let mut c = KController::new(rng.gen_f64(), 0.2, rng.normal() * 5.0, rng.gen_range(0, 3));
        let m = 1 + rng.gen_range(0, 64);
        for step in 0..40 {
            let snr = rng.normal() * 20.0;
            c.update(snr);
            assert!(
                (0.0..=1.0).contains(&c.k),
                "case {case} step {step}: k = {} out of range",
                c.k
            );
            let n = c.n_measure(m);
            assert!(n <= m, "case {case}: n_measure {n} > M {m}");
            assert!(n >= c.min_measure.min(m), "case {case}: floor violated");
        }
        assert_eq!(c.trace.len(), 41);
    });
}

#[test]
fn prop_select_final_respects_latency_band_and_minimizes_energy() {
    let spec = GpuArch::A100.spec();
    let space = ScheduleSpace::new(suites::MM1, &spec);
    forall(2, 40, |rng, case| {
        let n = 2 + rng.gen_range(0, 30);
        let pool: Vec<EvaluatedKernel> = (0..n)
            .map(|_| {
                let lat = 1e-4 * (1.0 + rng.gen_f64() * 5.0);
                let energy = 1e-3 * (1.0 + rng.gen_f64() * 10.0);
                EvaluatedKernel {
                    schedule: space.fallback(),
                    latency_s: lat,
                    energy_j: energy,
                    avg_power_w: energy / lat,
                    energy_measured: true,
                }
            })
            .collect();
        let best = select_final(&pool);
        let min_lat = pool.iter().map(|e| e.latency_s).fold(f64::INFINITY, f64::min);
        let cutoff = min_lat * (1.0 + FINAL_LATENCY_TOL);
        assert!(best.latency_s <= cutoff + 1e-15, "case {case}: outside band");
        for e in &pool {
            if e.latency_s <= cutoff {
                assert!(
                    best.energy_j <= e.energy_j + 1e-15,
                    "case {case}: {} not minimal (saw {})",
                    best.energy_j,
                    e.energy_j
                );
            }
        }
    });
}

#[test]
fn prop_worker_pool_preserves_order_for_any_topology() {
    forall(3, 5, |rng, case| {
        let n_workers = 1 + rng.gen_range(0, 6);
        let queue_cap = 1 + rng.gen_range(0, 4);
        let n_jobs = 1 + rng.gen_range(0, 6);
        let mut pool = WorkerPool::new(n_workers, queue_cap);
        let workloads = [suites::MM1, suites::MV3, suites::CONV2];
        for j in 0..n_jobs {
            pool.submit(SearchJob {
                name: format!("job{j}"),
                workload: workloads[j % workloads.len()],
                cfg: SearchConfig {
                    gpu: GpuArch::A100,
                    mode: SearchMode::LatencyOnly,
                    population: 16,
                    m_latency_keep: 4,
                    rounds: 2,
                    patience: 0,
                    seed: j as u64,
                    ..Default::default()
                },
            });
        }
        let results = pool.finish();
        assert_eq!(results.len(), n_jobs, "case {case}");
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i, "case {case}: order broken");
            assert_eq!(r.name, format!("job{i}"));
            assert!(r.worker < n_workers);
        }
    });
}

#[test]
fn prop_schedule_space_roundtrips_mutation_chains() {
    // Any chain of mutations from any start stays legal and keeps the
    // derived geometry consistent (block sizes = threads * regs).
    forall(4, 20, |rng, case| {
        let arch = [GpuArch::A100, GpuArch::Rtx4090, GpuArch::P100][rng.gen_range(0, 3)];
        let spec = arch.spec();
        let workloads = suites::all_named();
        let (_, w) = workloads[rng.gen_range(0, workloads.len())];
        let space = ScheduleSpace::new(w, &spec);
        let mut s = space.sample(rng);
        for step in 0..60 {
            s = ecokernel::schedule::mutation::mutate_one(&space, &s, rng);
            assert!(space.is_legal(&s), "case {case} step {step}: illegal {s}");
            assert_eq!(s.block_m(), s.threads_m * s.reg_m);
            assert_eq!(s.block_n(), s.threads_n * s.reg_n);
            assert_eq!(s.tile_k % s.unroll_k, 0);
            let g = w.gemm_view();
            assert!(s.grid(&g) >= 1);
            assert!(s.k_steps(&g) >= 1);
        }
    });
}

#[test]
fn prop_measurement_clock_merge_is_additive() {
    use ecokernel::nvml::MeasurementClock;
    forall(5, 30, |rng, case| {
        let mk = |rng: &mut Rng| {
            let mut c = MeasurementClock::new();
            c.charge_warmup(rng.gen_f64() * 5.0);
            c.charge_kernel_exec(rng.gen_f64() * 10.0);
            c.charge_latency_eval(rng.gen_f64());
            c.charge_model_predict(rng.gen_f64() * 0.01);
            c.charge_model_train(rng.gen_f64() * 0.1);
            c.note_energy_measurement();
            c
        };
        let a = mk(rng);
        let b = mk(rng);
        let mut merged = a.clone();
        merged.merge(&b);
        let sum = a.total_s + b.total_s;
        assert!(
            (merged.total_s - sum).abs() < 1e-12,
            "case {case}: {} != {}",
            merged.total_s,
            sum
        );
        assert_eq!(merged.n_energy_measurements, 2);
        // total equals the sum of the parts.
        let parts = merged.warmup_s
            + merged.kernel_exec_s
            + merged.latency_eval_s
            + merged.model_predict_s
            + merged.model_train_s;
        assert!((merged.total_s - parts).abs() < 1e-9, "case {case}: parts drift");
    });
}
