//! Integration: the full search stack (schedule space -> simulator ->
//! NVML-sim -> cost model -> Algorithm 1) across modes and workloads.

use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
use ecokernel::search::{run_search, FINAL_LATENCY_TOL};
use ecokernel::workload::suites;

fn cfg(gpu: GpuArch, mode: SearchMode, seed: u64) -> SearchConfig {
    SearchConfig {
        gpu,
        mode,
        seed,
        population: 48,
        m_latency_keep: 12,
        rounds: 6,
        patience: 0,
        ..Default::default()
    }
}

#[test]
fn energy_aware_beats_ansor_on_energy_across_operator_families() {
    // The Table-2 headline, one operator per family.
    for (i, w) in [suites::MM1, suites::MV3, suites::CONV2].into_iter().enumerate() {
        let seed = 10 + i as u64;
        let ansor = run_search(w, &cfg(GpuArch::A100, SearchMode::LatencyOnly, seed));
        let ours = run_search(w, &cfg(GpuArch::A100, SearchMode::EnergyAware, seed));
        assert!(
            ours.best.energy_j <= ansor.best.energy_j * 1.02,
            "{w}: ours {} mJ vs ansor {} mJ",
            ours.best.energy_j * 1e3,
            ansor.best.energy_j * 1e3
        );
        // Latency stays in the same class.
        assert!(
            ours.best.latency_s <= ansor.best.latency_s * (1.0 + 3.0 * FINAL_LATENCY_TOL),
            "{w}: latency regressed {} vs {}",
            ours.best.latency_s,
            ansor.best.latency_s
        );
    }
}

#[test]
fn works_on_all_simulated_gpus() {
    for gpu in [GpuArch::A100, GpuArch::Rtx4090, GpuArch::P100, GpuArch::V100] {
        let out = run_search(suites::MM1, &cfg(gpu, SearchMode::EnergyAware, 3));
        assert!(out.best.energy_j > 0.0 && out.best.latency_s > 0.0, "{gpu}");
        assert!(out.best.avg_power_w < gpu.spec().tdp_w * 1.02, "{gpu}");
    }
}

#[test]
fn k_controller_reduces_measurements_vs_nvml_only() {
    let w = suites::MM_4090;
    let seed = 500;
    let mut c = cfg(GpuArch::A100, SearchMode::EnergyAware, seed);
    c.mu_snr_db = -5.0;
    c.rounds = 8;
    let ours = run_search(w, &c);
    c.mode = SearchMode::EnergyNvmlOnly;
    let nvml = run_search(w, &c);
    assert!(
        (ours.n_energy_measurements() as f64)
            < nvml.n_energy_measurements() as f64 * 0.85,
        "ours {} vs nvml {}",
        ours.n_energy_measurements(),
        nvml.n_energy_measurements()
    );
    assert!(ours.clock.total_s < nvml.clock.total_s);
    // Search quality must not collapse: within 15% energy of NVML-only
    // at this deliberately tiny budget (paper-effort runs in
    // EXPERIMENTS.md show parity).
    assert!(
        ours.best.energy_j <= nvml.best.energy_j * 1.15,
        "quality loss: {} vs {}",
        ours.best.energy_j,
        nvml.best.energy_j
    );
}

#[test]
fn outcomes_are_reproducible_and_seed_sensitive() {
    let c = cfg(GpuArch::A100, SearchMode::EnergyAware, 42);
    let a = run_search(suites::CONV2, &c);
    let b = run_search(suites::CONV2, &c);
    assert_eq!(a.best.schedule, b.best.schedule);
    assert_eq!(a.best.energy_j, b.best.energy_j);
    assert_eq!(a.k_trace, b.k_trace);

    let mut c2 = c.clone();
    c2.seed = 43;
    let d = run_search(suites::CONV2, &c2);
    // Different seeds explore differently (almost surely different pools).
    assert_ne!(
        a.measured_pool.len() + a.rounds.len() * 1000 + a.n_latency_evals,
        d.measured_pool.len() + d.rounds.len() * 1000 + d.n_latency_evals + usize::MAX / 2,
        "trivially true; the real check is below"
    );
    assert!(a.best.schedule != d.best.schedule || a.best.energy_j != d.best.energy_j);
}

#[test]
fn best_kernel_is_always_from_the_measured_pool() {
    let out = run_search(suites::MM3, &cfg(GpuArch::A100, SearchMode::EnergyAware, 9));
    assert!(out.best.energy_measured);
    assert!(out
        .measured_pool
        .iter()
        .any(|e| e.schedule == out.best.schedule && e.energy_j == out.best.energy_j));
    // And it respects the final-selection latency tolerance.
    let best_lat = out
        .measured_pool
        .iter()
        .map(|e| e.latency_s)
        .fold(f64::INFINITY, f64::min);
    assert!(out.best.latency_s <= best_lat * (1.0 + FINAL_LATENCY_TOL) + 1e-12);
}

#[test]
fn round_telemetry_is_monotone_and_complete() {
    let out = run_search(suites::MM2, &cfg(GpuArch::A100, SearchMode::EnergyAware, 4));
    assert_eq!(out.rounds.len(), 6);
    for (i, r) in out.rounds.iter().enumerate() {
        assert_eq!(r.round, i);
        assert!(r.best_energy_j.is_finite());
        assert!(r.elapsed_s >= 0.0);
    }
    // Best-so-far energy never increases.
    for w in out.rounds.windows(2) {
        assert!(w[1].best_energy_j <= w[0].best_energy_j + 1e-12);
        assert!(w[1].elapsed_s >= w[0].elapsed_s);
    }
}

#[test]
fn patience_stops_early() {
    let mut c = cfg(GpuArch::A100, SearchMode::EnergyAware, 5);
    c.rounds = 30;
    c.patience = 2;
    let out = run_search(suites::MM1, &c);
    assert!(out.rounds.len() < 30, "patience must trigger, got {} rounds", out.rounds.len());
}
