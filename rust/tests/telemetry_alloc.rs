//! Telemetry hot-path guard: recording a served request's metrics —
//! stage trace accumulation, both reply-clock histograms, the
//! per-stage histograms, quantile reads, and distributed-trace id
//! handling (parse/mint/compare) — performs **zero heap allocations**.
//! This is the contract that lets the daemon fold telemetry under the
//! state-lock acquisition the exact-hit path already pays, without
//! adding latency or allocator contention.
//!
//! Guarded by a counting `#[global_allocator]` with a const-init
//! thread-local counter (no lazy TLS state, so counting itself cannot
//! allocate). One test in this file on purpose: the counter is
//! per-thread, so no other test can race it.

use ecokernel::serve::ServeMetrics;
use ecokernel::telemetry::{
    ledger_family_index, ledger_gpu_index, LogHistogram, Stage, StageTrace, TraceId, UNATTRIBUTED,
};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::hint::black_box;

thread_local! {
    static ALLOC_CALLS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOC_CALLS.with(|c| c.get())
}

#[test]
fn hit_path_telemetry_performs_zero_heap_allocations() {
    let mut m = ServeMetrics::default();

    // Warm-up: touch every code path once so one-time lazy state
    // (TLS registration, test-harness buffers) is paid outside the
    // measured window.
    let mut warm = StageTrace::new();
    warm.add(Stage::Parse, 1e-6);
    warm.add(Stage::ShardRead, 2e-6);
    m.record_reply(true, 5e-5, 3e-5, &warm);
    m.record_stage(Stage::ReplyWrite, 4e-6);
    black_box(m.p99_reply_s());
    black_box(TraceId::mint());
    black_box(TraceId::from_hex("feedc0de"));
    m.ledger.record_saved(0, 0, 1e-3);
    m.ledger.record_paid(0, 0, 1e-3);

    let before = allocations();
    for i in 0..10_000u64 {
        // Exactly what the daemon does per exact hit: build the stack
        // trace, accumulate stages, record both clocks + stages, and
        // (as `stats` polls do) read quantiles back.
        let mut trace = StageTrace::new();
        trace.add(Stage::Parse, 1e-6 + i as f64 * 1e-12);
        trace.add(Stage::ShardRead, 2e-6);
        trace.add(Stage::ShardRead, 1e-6); // re-read, as a miss would
        m.record_reply(true, 5e-5, 3e-5 + i as f64 * 1e-12, &trace);
        m.record_stage(Stage::ReplyWrite, 4e-6);
        black_box(m.p50_reply_s());
        black_box(m.p99_reply_s());
        black_box(m.hit_rate());
        // Distributed-tracing id handling an exact hit pays: parse a
        // wire-supplied id, mint a fallback, copy + compare. (Only the
        // MISS path renders `to_hex` or opens a trace — those allocate
        // and are deliberately NOT in this loop.)
        let wire = black_box(TraceId::from_hex("feedc0dedeadbeef")).unwrap();
        let minted = black_box(TraceId::mint());
        black_box(wire == minted);
        black_box(wire.min(minted));
        // Energy-ledger accounting on the same hit: label lookups are
        // &str compares over static tables, recording is fixed-array
        // adds. An unattributed hit (no stored baseline) stays free
        // too — it must never fall back to a String key.
        let gpu = black_box(ledger_gpu_index(black_box("a100"))).unwrap();
        let family = black_box(ledger_family_index(black_box("mm")));
        m.ledger.record_saved(gpu, family, 2.5e-3 + i as f64 * 1e-12);
        m.ledger.record_saved(gpu, UNATTRIBUTED, 0.0);
        m.ledger.record_paid(gpu, family, 7.0e-2);
        black_box(m.ledger.total_saved_j());
    }
    // Fleet aggregation primitives are allocation-free too: clone and
    // merge are fixed-size array copies/adds.
    let snapshot: LogHistogram = m.reply_wall().clone();
    let mut merged = snapshot.clone();
    merged.merge(m.reply_wall());
    black_box(merged.quantile(99.0));
    black_box(merged.mean());
    let after = allocations();

    assert_eq!(m.n_requests, 10_001);
    assert_eq!(m.ledger.n_hits(0, 0), 10_001);
    assert_eq!(m.ledger.n_hits(0, UNATTRIBUTED), 10_000);
    assert_eq!(m.ledger.n_searches(0, 0), 10_001);
    assert_eq!(
        after - before,
        0,
        "telemetry hot path allocated {} time(s) in 10k hit records",
        after - before
    );
}
