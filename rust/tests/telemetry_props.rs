//! Property tests for the telemetry log2-bucket histogram — the
//! guarantees the serving stack leans on:
//!
//! * merge is associative and commutative, and merging histograms of
//!   two streams equals the histogram of the concatenated stream
//!   (exactly — this is what makes fleet-wide quantiles honest);
//! * quantiles track the true sample quantiles within one log2 bucket
//!   (a factor of 2);
//! * memory stays fixed no matter how many samples are recorded.

use ecokernel::telemetry::{LogHistogram, N_BUCKETS};
use ecokernel::util::rng::Rng;
use ecokernel::util::stats::percentile;

/// Latency-shaped positive samples spanning ~6 decades (ns to s).
fn sample_stream(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            // Log-uniform base in [1e-9, 1e-3) with occasional slow
            // outliers, mimicking a hit-dominated reply distribution.
            let base = 10f64.powf(-9.0 + 6.0 * rng.gen_f64());
            if rng.gen_bool(0.02) {
                base * 1e4
            } else {
                base
            }
        })
        .collect()
}

fn hist_of(samples: &[f64]) -> LogHistogram {
    let mut h = LogHistogram::new();
    for &v in samples {
        h.record(v);
    }
    h
}

#[test]
fn merge_is_commutative_and_associative() {
    let a = hist_of(&sample_stream(1, 500));
    let b = hist_of(&sample_stream(2, 300));
    let c = hist_of(&sample_stream(3, 700));

    let mut ab = a.clone();
    ab.merge(&b);
    let mut ba = b.clone();
    ba.merge(&a);
    assert_eq!(ab, ba, "a∪b == b∪a");

    let mut ab_c = ab.clone();
    ab_c.merge(&c);
    let mut bc = b.clone();
    bc.merge(&c);
    let mut a_bc = a.clone();
    a_bc.merge(&bc);
    assert_eq!(ab_c, a_bc, "(a∪b)∪c == a∪(b∪c)");
}

#[test]
fn merged_histogram_equals_histogram_of_concatenated_stream() {
    let xs = sample_stream(10, 800);
    let ys = sample_stream(11, 600);
    let concat: Vec<f64> = xs.iter().chain(&ys).copied().collect();

    let mut merged = hist_of(&xs);
    merged.merge(&hist_of(&ys));
    let direct = hist_of(&concat);

    assert_eq!(merged, direct);
    for p in [50.0, 90.0, 99.0] {
        assert_eq!(merged.quantile(p), direct.quantile(p), "p{p}");
    }
}

#[test]
fn quantiles_track_true_quantiles_within_one_bucket() {
    for seed in 0..8u64 {
        let xs = sample_stream(100 + seed, 2000);
        let h = hist_of(&xs);
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [50.0, 90.0, 99.0] {
            let est = h.quantile(p);
            // Against the sample at the histogram's own nearest rank
            // (ceil(p·n/100)), the bound is tight: the estimate is the
            // geometric midpoint of that sample's bucket, so at most
            // √2 away in either direction — well inside a factor of 2.
            let rank = ((p / 100.0) * xs.len() as f64).ceil().max(1.0) as usize;
            let truth = sorted[rank.min(xs.len()) - 1];
            assert!(
                est >= truth / 2.0 && est <= truth * 2.0,
                "seed {seed} p{p}: est {est:.3e} vs rank-true {truth:.3e}"
            );
            // Against the repo's `stats::percentile` (a slightly
            // different rank convention) allow one extra bucket of
            // slack for the rank difference in sparse tails.
            let ref_truth = percentile(&xs, p);
            assert!(
                est >= ref_truth / 4.0 && est <= ref_truth * 4.0,
                "seed {seed} p{p}: est {est:.3e} vs percentile {ref_truth:.3e}"
            );
        }
    }
}

#[test]
fn quantile_is_bounded_by_observed_min_and_max() {
    let xs = sample_stream(42, 1000);
    let h = hist_of(&xs);
    let (lo, hi) = (h.min(), h.max());
    for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
        let q = h.quantile(p);
        assert!(q >= lo && q <= hi, "p{p}: {q:.3e} outside [{lo:.3e}, {hi:.3e}]");
    }
    // Quantiles are monotone in p.
    assert!(h.quantile(99.0) >= h.quantile(50.0));
    assert!(h.quantile(50.0) >= h.quantile(1.0));
}

#[test]
fn memory_stays_fixed_under_ten_million_records() {
    // The histogram is a fixed-size value type: recording never
    // allocates, so size_of is the whole footprint.
    assert!(std::mem::size_of::<LogHistogram>() <= N_BUCKETS * 8 + 64);

    let mut h = LogHistogram::new();
    let mut rng = Rng::seed_from_u64(9);
    let mut sum = 0.0f64;
    for _ in 0..10_000_000u64 {
        let v = 10f64.powf(-9.0 + 6.0 * rng.gen_f64());
        h.record(v);
        sum += v;
    }
    assert_eq!(h.count(), 10_000_000);
    assert!((h.sum() - sum).abs() <= sum * 1e-9);
    let p50 = h.quantile(50.0);
    assert!(p50 > 0.0 && p50.is_finite());
    assert!(h.quantile(99.0) >= p50);
}

#[test]
fn degenerate_inputs_land_in_the_underflow_bucket() {
    let mut h = LogHistogram::new();
    h.record(0.0);
    h.record(-3.0);
    h.record(f64::NAN);
    h.record(f64::INFINITY);
    assert_eq!(h.count(), 4);
    // Everything non-finite or ≤ 0 clamps into bucket 0 rather than
    // poisoning the distribution; quantiles stay finite.
    assert!(h.quantile(50.0).is_finite());
}
