//! Property-based tests on the cost-model stack (hand-rolled property
//! harness: seeded random cases, counterexample printed on failure —
//! the offline build has no proptest crate).

use ecokernel::costmodel::{eq1_weight, BoostParams, Gbdt, PaperWeightedSquaredError, SquaredError};
use ecokernel::util::{stats, Rng};

/// Run `n` random cases of a property.
fn forall(seed: u64, n: usize, mut prop: impl FnMut(&mut Rng, usize)) {
    let mut rng = Rng::seed_from_u64(seed);
    for case in 0..n {
        let mut case_rng = rng.fork(case as u64);
        prop(&mut case_rng, case);
    }
}

fn random_dataset(rng: &mut Rng, n: usize, d: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    // Random piecewise-linear target over random features.
    let coef: Vec<f64> = (0..d).map(|_| rng.normal() * 2.0).collect();
    let thresh: Vec<f64> = (0..d).map(|_| rng.gen_f64()).collect();
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.gen_f64()).collect();
        let y: f64 = x
            .iter()
            .zip(&coef)
            .zip(&thresh)
            .map(|((xi, c), t)| if xi > t { c * xi } else { -c * (1.0 - xi) })
            .sum();
        xs.push(x);
        ys.push(y);
    }
    (xs, ys)
}

#[test]
fn prop_gbdt_predictions_bounded_by_target_hull() {
    // Tree leaves are Newton steps toward targets: predictions must stay
    // inside (a small expansion of) the target range.
    forall(1, 12, |rng, case| {
        let n = 80 + rng.gen_range(0, 200);
        let d = 2 + rng.gen_range(0, 4);
        let (xs, ys) = random_dataset(rng, n, d);
        let w = vec![1.0; n];
        let p = BoostParams { n_trees: 30, max_depth: 4, ..Default::default() };
        let model = Gbdt::fit(&xs, &ys, &w, &SquaredError, &p, rng);
        let lo = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let span = (hi - lo).max(1e-9);
        for x in xs.iter().take(50) {
            let pred = model.predict(x);
            assert!(
                pred >= lo - 0.25 * span && pred <= hi + 0.25 * span,
                "case {case}: pred {pred} escapes hull [{lo}, {hi}]"
            );
        }
    });
}

#[test]
fn prop_gbdt_more_trees_never_hurt_training_fit() {
    forall(2, 8, |rng, case| {
        let n = 120 + rng.gen_range(0, 100);
        let (xs, ys) = random_dataset(rng, n, 3);
        let w = vec![1.0; n];
        let mse = |trees: usize, rng: &mut Rng| {
            let p = BoostParams { n_trees: trees, max_depth: 4, ..Default::default() };
            let m = Gbdt::fit(&xs, &ys, &w, &SquaredError, &p, rng);
            xs.iter().zip(&ys).map(|(x, y)| (m.predict(x) - y).powi(2)).sum::<f64>() / n as f64
        };
        let few = mse(10, &mut rng.fork(1));
        let many = mse(60, &mut rng.fork(1));
        assert!(
            many <= few * 1.05,
            "case {case}: 60 trees mse {many} worse than 10 trees {few}"
        );
    });
}

#[test]
fn prop_gbdt_invariant_to_sample_order() {
    forall(3, 6, |rng, case| {
        let n = 100;
        let (mut xs, mut ys) = random_dataset(rng, n, 3);
        let w = vec![1.0; n];
        let p = BoostParams { n_trees: 20, max_depth: 4, colsample: 1.0, ..Default::default() };
        let m1 = Gbdt::fit(&xs, &ys, &w, &SquaredError, &p, &mut Rng::seed_from_u64(1));
        // Shuffle consistently.
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let xs2: Vec<Vec<f64>> = idx.iter().map(|&i| xs[i].clone()).collect();
        let ys2: Vec<f64> = idx.iter().map(|&i| ys[i]).collect();
        xs = xs2;
        ys = ys2;
        let m2 = Gbdt::fit(&xs, &ys, &w, &SquaredError, &p, &mut Rng::seed_from_u64(1));
        for x in xs.iter().take(30) {
            let (a, b) = (m1.predict(x), m2.predict(x));
            assert!(
                (a - b).abs() < 1e-6 * (1.0 + a.abs()),
                "case {case}: order-dependent predictions {a} vs {b}"
            );
        }
    });
}

#[test]
fn prop_eq1_weighting_shifts_accuracy_to_low_targets() {
    // Over random datasets with wide dynamic range, Eq. 1 weighting must
    // not degrade relative error on the lowest-target tercile.
    forall(4, 6, |rng, case| {
        let n = 300;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.gen_f64();
            let b = rng.gen_f64();
            xs.push(vec![a, b]);
            ys.push(0.05 + 8.0 * a * a + 0.3 * b);
        }
        let p = BoostParams { n_trees: 40, max_depth: 4, ..Default::default() };
        let w_eq1: Vec<f64> = ys.iter().map(|&y| eq1_weight(y)).collect();
        let w_flat = vec![1.0; n];
        let weighted =
            Gbdt::fit(&xs, &ys, &w_eq1, &PaperWeightedSquaredError, &p, &mut rng.fork(1));
        let flat = Gbdt::fit(&xs, &ys, &w_flat, &SquaredError, &p, &mut rng.fork(1));
        let cutoff = stats::percentile(&ys, 33.0);
        let rel = |m: &Gbdt| {
            let mut e = 0.0;
            let mut c = 0;
            for (x, y) in xs.iter().zip(&ys) {
                if *y <= cutoff {
                    e += ((m.predict(x) - y) / y).abs();
                    c += 1;
                }
            }
            e / c as f64
        };
        let (rw, rf) = (rel(&weighted), rel(&flat));
        assert!(rw <= rf * 1.15, "case {case}: weighted {rw} much worse than flat {rf}");
    });
}

#[test]
fn prop_snr_monotone_in_noise() {
    forall(5, 10, |rng, case| {
        let n = 30 + rng.gen_range(0, 50);
        let measured: Vec<f64> = (0..n).map(|_| 1.0 + rng.gen_f64() * 9.0).collect();
        let mut last_snr = f64::INFINITY;
        for noise in [0.01, 0.05, 0.2, 0.8] {
            let pred: Vec<f64> = measured
                .iter()
                .map(|m| m + noise * rng.normal() * m)
                .collect();
            let snr = stats::snr_db(&pred, &measured);
            assert!(
                snr < last_snr + 3.0,
                "case {case}: SNR not (approx) decreasing with noise: {snr} after {last_snr}"
            );
            last_snr = snr;
        }
    });
}
