//! Integration: fleet serving end to end (the ISSUE 3 acceptance
//! criteria) — two daemons mounting one store, the same client bytes
//! over `unix:` and `tcp:`, a duplicated miss searched exactly once
//! fleet-wide, lease-fenced compaction racing and reclaiming after a
//! crash, epoch-fenced write-backs from stale holders, and admission
//! control shedding cold keys under queue saturation.
#![cfg(unix)]

use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
use ecokernel::fleet::InflightTable;
use ecokernel::serve::{
    merged_health, merged_metrics, BatchError, BatchRequest, Daemon, DaemonConfig, DaemonHandle,
    HealthReply, HealthStatus, KernelReply, MetricsReply, Op, ServeAddr, ServeClient, StatsReply,
    TraceReply,
};
use ecokernel::store::lease::Lease;
use ecokernel::store::sharded::{shard_lease_name, LEASES_DIR};
use ecokernel::store::{config_fingerprint, serve_key, ShardedStore, TuningRecord};
use ecokernel::telemetry::{
    ledger_family_index, ledger_gpu_index, LEDGER_FAMILIES, LEDGER_GPUS, N_BUCKETS,
};
use ecokernel::workload::{suites, Workload};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

const DRAIN_TIMEOUT: Duration = Duration::from_secs(180);

// Thin shims over the typed op API, so every test reads as one call
// per wire operation.

fn get_kernel(
    client: &mut ServeClient,
    workload: Workload,
    gpu: Option<GpuArch>,
    mode: Option<SearchMode>,
) -> anyhow::Result<KernelReply> {
    client.call(Op::GetKernel { workload, gpu, mode, trace: None })?.into_kernel()
}

fn get_kernel_batch(
    client: &mut ServeClient,
    requests: &[BatchRequest],
) -> anyhow::Result<Vec<Result<KernelReply, BatchError>>> {
    let n = requests.len();
    client.call(Op::Batch(requests.to_vec()))?.into_batch(n)
}

fn stats(client: &mut ServeClient) -> anyhow::Result<StatsReply> {
    client.call(Op::Stats)?.into_stats()
}

fn metrics(client: &mut ServeClient) -> anyhow::Result<MetricsReply> {
    client.call(Op::Metrics)?.into_metrics()
}

fn traces(client: &mut ServeClient, slowest: usize) -> anyhow::Result<TraceReply> {
    client.call(Op::Traces { slowest })?.into_traces()
}

fn health(client: &mut ServeClient) -> anyhow::Result<HealthReply> {
    client.call(Op::Health)?.into_health()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ecokernel_fleet_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn quick_search(seed: u64) -> SearchConfig {
    let mut search = SearchConfig {
        gpu: GpuArch::A100,
        mode: SearchMode::EnergyAware,
        population: 24,
        m_latency_keep: 6,
        rounds: 3,
        patience: 0,
        seed,
        ..Default::default()
    };
    search.serve.n_workers = 1;
    search.serve.n_shards = 4;
    search
}

fn spawn_on(addr: ServeAddr, store_dir: &Path, search: SearchConfig) -> DaemonHandle {
    let store_dir = store_dir.to_path_buf();
    Daemon::spawn(DaemonConfig { addr, store_dir, search }, None).unwrap()
}

fn record_for(w: Workload, seed: u64) -> (TuningRecord, SearchConfig) {
    let cfg = SearchConfig {
        population: 24,
        m_latency_keep: 6,
        rounds: 3,
        patience: 0,
        seed,
        ..Default::default()
    };
    let out = ecokernel::search::run_search(w, &cfg);
    (TuningRecord::from_outcome(&out, &cfg), cfg)
}

fn key_of(rec: &TuningRecord) -> String {
    serve_key(&rec.workload_id, &rec.gpu, &rec.mode, &rec.fingerprint)
}

/// A cheap handmade record (no search) whose serve key matches `cfg`:
/// enough structure for routing, lookups, and neighbor selection.
fn hand_record(w: Workload, cfg: &SearchConfig) -> TuningRecord {
    let mut rec = TuningRecord::synthetic(w, cfg.gpu, cfg.seed);
    rec.mode = cfg.mode.name().to_string();
    rec.fingerprint = config_fingerprint(cfg);
    rec
}

/// The same client bytes produce byte-identical replies over `unix:`
/// and `tcp:` — the frame protocol is transport-agnostic.
#[test]
fn same_client_bytes_work_over_unix_and_tcp() {
    let dir_unix = tmp_dir("parity_unix");
    let dir_tcp = tmp_dir("parity_tcp");
    let unix_daemon = spawn_on(
        ServeAddr::Unix(dir_unix.join("eco.sock")),
        &dir_unix,
        quick_search(7),
    );
    let tcp_daemon = spawn_on(
        ServeAddr::Tcp("127.0.0.1:0".to_string()),
        &dir_tcp,
        quick_search(7),
    );
    assert!(matches!(tcp_daemon.addr, ServeAddr::Tcp(_)), "{}", tcp_daemon.addr);

    let mut ca = ServeClient::connect(&unix_daemon.addr).unwrap();
    let mut cb = ServeClient::connect(&tcp_daemon.addr).unwrap();
    let frames = [
        // A real kernel request against two identically-fresh stores…
        r#"{"v":1,"op":"get_kernel","id":"parity1","workload":"MM1"}"#,
        // …and the protocol's error surface.
        r#"{"v":1,"op":"get_kernel","id":"parity2","workload":"MM99"}"#,
        r#"{"v":9,"op":"stats","id":"parity3"}"#,
    ];
    for frame in frames {
        let over_unix = ca.roundtrip_raw(frame).unwrap();
        let over_tcp = cb.roundtrip_raw(frame).unwrap();
        assert_eq!(over_unix, over_tcp, "reply bytes must not depend on the wire: {frame}");
    }

    for (mut client, handle) in [(ca, unix_daemon), (cb, tcp_daemon)] {
        client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
        client.shutdown().unwrap();
        handle.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir_unix);
    let _ = std::fs::remove_dir_all(&dir_tcp);
}

/// Two daemons, one store: a miss duplicated across daemons triggers
/// exactly one search fleet-wide, the record propagates to both, and
/// both then serve concurrent exact hits.
#[test]
fn two_daemons_one_store_search_once_fleet_wide() {
    let dir = tmp_dir("fleet");
    let a = spawn_on(ServeAddr::Unix(dir.join("a.sock")), &dir, quick_search(9));
    let b = spawn_on(ServeAddr::Tcp("127.0.0.1:0".to_string()), &dir, quick_search(9));

    let mut ca = ServeClient::connect(&a.addr).unwrap();
    let mut cb = ServeClient::connect(&b.addr).unwrap();

    // Duplicate the same miss across both daemons. On a fresh store
    // both replies are the search-free static tier (ISSUE 9): no
    // neighbor exists, so each daemon answers from the static ranking
    // — yet the key is still searched only once fleet-wide.
    let on_a = get_kernel(&mut ca, suites::MM1, None, None).unwrap();
    assert!(!on_a.hit && on_a.enqueued, "first miss claims the key and searches");
    assert_eq!(on_a.tier.name(), "static", "fresh store: static-tier reply");
    let on_b = get_kernel(&mut cb, suites::MM1, None, None).unwrap();
    if !on_b.hit {
        assert!(!on_b.enqueued, "duplicate miss coalesces into A's in-flight claim");
        assert_eq!(on_b.tier.name(), "static");
        assert_eq!(on_b.schedule, on_a.schedule, "static ranking is deterministic fleet-wide");
    }

    // A's background search lands; B sees it through store refresh.
    ca.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    cb.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    let hit_b = cb.get_kernel_wait(suites::MM1, None, None, DRAIN_TIMEOUT).unwrap();
    assert!(hit_b.hit, "B serves A's search result from the shared store");
    let hit_a = get_kernel(&mut ca, suites::MM1, None, None).unwrap();
    assert!(hit_a.hit);
    assert_eq!(hit_a.schedule, hit_b.schedule, "one record serves the whole fleet");

    // Concurrent exact hits from both daemons.
    for _ in 0..3 {
        assert!(get_kernel(&mut ca, suites::MM1, None, None).unwrap().hit);
        assert!(get_kernel(&mut cb, suites::MM1, None, None).unwrap().hit);
    }

    // Exactly one search ran fleet-wide, and both daemons agree on the
    // store contents.
    let sa = stats(&mut ca).unwrap();
    let sb = stats(&mut cb).unwrap();
    assert_eq!(
        sa.n_searches_done + sb.n_searches_done,
        1,
        "a: {}, b: {}",
        sa.n_searches_done,
        sb.n_searches_done
    );
    assert_eq!(sa.n_records, 1);
    assert_eq!(sb.n_records, 1);
    assert_eq!(sa.shard_records.iter().sum::<usize>(), 1, "{:?}", sa.shard_records);

    for (mut client, handle) in [(ca, a), (cb, b)] {
        client.shutdown().unwrap();
        handle.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The fleet-telemetry pin (ISSUE 6): merging two TCP daemons'
/// `metrics` replies equals the histogram of the UNION of their sample
/// streams — asserted per bucket across all 64 buckets, plus
/// count/sum/min/max and summed counters, in both merge orders.
#[test]
fn fleet_metrics_merge_equals_union_of_samples() {
    let dir = tmp_dir("metrics_merge");
    // Freeze the background refresh loops (both notify and the poll
    // fallback out of reach): the only counter/histogram mutations are
    // the requests this test sends, so the merge pin is exact. Misses
    // still see peer write-backs through the on-miss targeted refresh.
    let mut search = quick_search(17);
    search.fleet.notify_interval_ms = 3_600_000;
    search.fleet.poll_interval_ms = 3_600_000;
    let a = spawn_on(ServeAddr::Tcp("127.0.0.1:0".to_string()), &dir, search.clone());
    let b = spawn_on(ServeAddr::Tcp("127.0.0.1:0".to_string()), &dir, search);
    let mut ca = ServeClient::connect(&a.addr).unwrap();
    let mut cb = ServeClient::connect(&b.addr).unwrap();

    // Distinct traffic shapes per daemon: A pays the miss + search,
    // then both serve hits (B's first request ingests A's record via
    // the targeted on-miss refresh).
    assert!(get_kernel(&mut ca, suites::MM1, None, None).unwrap().enqueued);
    ca.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    for _ in 0..3 {
        assert!(get_kernel(&mut ca, suites::MM1, None, None).unwrap().hit);
    }
    assert!(cb.get_kernel_wait(suites::MM1, None, None, DRAIN_TIMEOUT).unwrap().hit);
    assert!(get_kernel(&mut cb, suites::MM1, None, None).unwrap().hit);

    let ma = metrics(&mut ca).unwrap();
    let mb = metrics(&mut cb).unwrap();
    assert!(ma.reply_wall_s.count() >= 4);
    assert!(mb.reply_wall_s.count() >= 2);

    // The fleet client's merged view (fresh connections — the daemons
    // are quiescent, so it sees exactly what `ma`/`mb` saw)...
    let fm = merged_metrics(&[a.addr.clone(), b.addr.clone()]).unwrap();
    assert!(fm.errors.is_empty(), "both daemons reachable: {:?}", fm.errors);
    let merged = fm.merged;
    // ...equals the histogram of the union of both daemons' samples:
    // every one of the 64 buckets is the elementwise sum.
    for hist in ["reply_wall_s", "reply_sim_s"] {
        let (m, x, y) = match hist {
            "reply_wall_s" => (&merged.reply_wall_s, &ma.reply_wall_s, &mb.reply_wall_s),
            _ => (&merged.reply_sim_s, &ma.reply_sim_s, &mb.reply_sim_s),
        };
        for i in 0..N_BUCKETS {
            assert_eq!(m.bucket(i), x.bucket(i) + y.bucket(i), "{hist} bucket {i}");
        }
        assert_eq!(m.count(), x.count() + y.count(), "{hist}");
        assert_eq!(m.sum(), x.sum() + y.sum(), "{hist}");
        assert_eq!(m.min(), x.min().min(y.min()), "{hist}");
        assert_eq!(m.max(), x.max().max(y.max()), "{hist}");
    }
    // Stage histograms and counters merge the same way.
    let mut expect = ma.clone();
    expect.merge(&mb);
    assert_eq!(merged.stages, expect.stages);
    assert_eq!(merged.counters, expect.counters);
    assert_eq!(merged.model, expect.model);
    assert!(
        merged.model.keys().any(|k| k.starts_with("model_dynamic_k/")),
        "A's search recorded per-regime model telemetry: {:?}",
        merged.model.keys().collect::<Vec<_>>()
    );
    assert_eq!(
        merged.counter("n_requests"),
        ma.counter("n_requests") + mb.counter("n_requests")
    );
    assert_eq!(merged.counter("n_searches_done"), 1, "one search fleet-wide");

    // Merge commutes: folding B into A equals folding A into B.
    let mut other_order = mb.clone();
    other_order.merge(&ma);
    assert_eq!(other_order.reply_wall_s, expect.reply_wall_s);
    assert_eq!(other_order.stages, expect.stages);
    assert_eq!(other_order.counters, expect.counters);

    for (mut client, handle) in [(ca, a), (cb, b)] {
        client.shutdown().unwrap();
        handle.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The push path (ISSUE 5): daemon B serves daemon A's write-back as
/// an exact hit through the notify channel alone — ZERO interval polls
/// (the fallback is configured out of reach) and no request-path
/// search on B.
#[test]
fn notify_delivers_foreign_writebacks_without_polling() {
    let dir = tmp_dir("notify");
    let mut search = quick_search(31);
    search.fleet.notify_interval_ms = 25;
    // Push the poll fallback out of reach: any freshness B gains must
    // come from notify.
    search.fleet.poll_interval_ms = 3_600_000;
    let a = spawn_on(ServeAddr::Unix(dir.join("a.sock")), &dir, search.clone());
    let b = spawn_on(ServeAddr::Unix(dir.join("b.sock")), &dir, search);
    let mut ca = ServeClient::connect(&a.addr).unwrap();
    let mut cb = ServeClient::connect(&b.addr).unwrap();

    // A searches MM1 and lands the write-back; B never requests it.
    assert!(get_kernel(&mut ca, suites::MM1, None, None).unwrap().enqueued);
    ca.wait_for_drain(DRAIN_TIMEOUT).unwrap();

    // B's refresh loop ingests A's announcement.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let s = stats(&mut cb).unwrap();
        if s.n_notify_refresh >= 1 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "B never saw A's notify announcement: {s:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // B's FIRST request for the key is a plain exact hit, served from
    // memory the push path filled.
    let hit = get_kernel(&mut cb, suites::MM1, None, None).unwrap();
    assert!(hit.hit, "B serves A's write-back via notify");
    assert_eq!(hit.source.name(), "store");

    let sb = stats(&mut cb).unwrap();
    assert_eq!(sb.n_poll_refresh, 0, "zero interval polls: freshness was pushed");
    assert!(sb.n_notify_refresh >= 1);
    assert_eq!(sb.n_searches_done, 0, "B never searched");
    assert_eq!(sb.n_enqueued, 0);
    let sa = stats(&mut ca).unwrap();
    assert_eq!(sa.n_notify_refresh, 0, "a daemon skips its own announcements");
    assert_eq!(sa.n_poll_refresh, 0);

    for (mut client, handle) in [(ca, a), (cb, b)] {
        client.shutdown().unwrap();
        handle.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Batched serving (ISSUE 5): a mixed batch of 8 requests over ONE
/// socket write produces exactly 8 positionally-matched replies —
/// hits at hit positions, misses at miss positions, an in-batch
/// duplicate coalescing instead of double-enqueueing.
#[test]
fn batch_of_eight_mixed_requests_is_positionally_matched() {
    let dir = tmp_dir("batch8");
    let handle = spawn_on(ServeAddr::Unix(dir.join("eco.sock")), &dir, quick_search(33));
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    // Warm MM1 so the batch has real hits in it.
    get_kernel(&mut client, suites::MM1, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();

    let requests: Vec<ecokernel::serve::BatchRequest> = vec![
        (suites::MM1, None, None), // hit
        (suites::MV3, None, None), // miss, enqueues
        (suites::MM1, None, None), // hit
        (suites::MV4, None, None), // miss, enqueues
        (suites::MV3, None, None), // duplicate miss: coalesces
        (suites::MM1, None, None), // hit
        (suites::MM2, None, None), // miss, enqueues
        (suites::MM1, None, None), // hit
    ];
    let replies = get_kernel_batch(&mut client, &requests).unwrap();
    assert_eq!(replies.len(), 8, "one reply per request");
    let replies: Vec<_> = replies.into_iter().map(|r| r.unwrap()).collect();
    // Positional matching: entry i answers request i (the client's
    // positional ids echo back in order).
    for (i, reply) in replies.iter().enumerate() {
        assert!(reply.id.ends_with(&format!(".{i}")), "reply {i} has id {}", reply.id);
    }
    let hits: Vec<bool> = replies.iter().map(|r| r.hit).collect();
    assert_eq!(hits, [true, false, true, false, false, true, false, true]);
    assert!(replies[1].enqueued, "first MV3 miss searches");
    assert!(!replies[4].enqueued, "duplicate MV3 within the batch coalesces");
    assert!(replies[3].enqueued && replies[6].enqueued);

    let s = client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert_eq!(s.n_batch_frames, 1, "one frame carried all eight");
    assert_eq!(s.n_batch_requests, 8);
    assert_eq!(s.n_searches_done, 4, "warm-up + 3 distinct batch misses");
    assert_eq!((s.n_hits, s.n_misses), (4, 5), "batch entries count as requests");

    // The pipelined queue/flush API is the same wire path. It is
    // deprecated in favor of `call(Op::Batch(..))` but contractually
    // alive for one release — this block IS its compat test.
    #[allow(deprecated)]
    {
        client.queue_get_kernel(suites::MM1, None, None);
        client.queue_get_kernel(suites::MV3, None, None);
        assert_eq!(client.queued_len(), 2);
        let flushed = client.flush_batch().unwrap();
        assert_eq!(client.queued_len(), 0);
        assert!(flushed.iter().all(|r| r.as_ref().unwrap().hit), "both landed earlier");
    }

    client.shutdown().unwrap();
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Old clients are untouched by batching: a plain `get_kernel` frame
/// is answered byte-identically across repeats (same id, same state)
/// — the PR-4 single-frame wire format did not move.
#[test]
fn single_get_kernel_frames_are_byte_stable() {
    let dir = tmp_dir("bytestable");
    let handle = spawn_on(ServeAddr::Unix(dir.join("eco.sock")), &dir, quick_search(35));
    let mut client = ServeClient::connect(&handle.addr).unwrap();
    get_kernel(&mut client, suites::MM1, None, None).unwrap();
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();

    let frame = r#"{"v":1,"op":"get_kernel","id":"pin1","workload":"MM1"}"#;
    let first = client.roundtrip_raw(frame).unwrap();
    let second = client.roundtrip_raw(frame).unwrap();
    assert_eq!(first, second, "identical request, identical bytes");
    assert!(first.contains(r#""result":"hit""#), "{first}");
    assert!(first.contains(r#""source":"store""#), "{first}");
    // A batch wrapping the same request carries the same payload per
    // entry (only the ids differ — they are client-chosen).
    let hit = get_kernel(&mut client, suites::MM1, None, None).unwrap();
    let batched =
        get_kernel_batch(&mut client, &[(suites::MM1, None, None)]).unwrap().remove(0).unwrap();
    assert_eq!(batched.schedule, hit.schedule);
    assert_eq!(batched.latency_s, hit.latency_s);
    assert_eq!(batched.energy_j, hit.energy_j);

    client.shutdown().unwrap();
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Lease contention: two stores on one directory race the same
/// eviction; leases serialize the rewrites and no retained record is
/// lost, no matter who wins.
#[test]
fn two_stores_racing_eviction_lose_no_retained_records() {
    let dir = tmp_dir("race");
    let s1 = ShardedStore::open_fleet(&dir, 2, "h1", 60_000).unwrap();
    let (rec_a, _) = record_for(suites::MM1, 20);
    let (rec_b, cfg_b) = record_for(suites::MV3, 21);
    let (rec_c, _) = record_for(suites::CONV2, 22);
    s1.append(rec_a).unwrap();
    s1.append(rec_b.clone()).unwrap();
    s1.append(rec_c).unwrap();
    s1.mark_served(&key_of(&rec_b)).unwrap();
    let s2 = ShardedStore::open_fleet(&dir, 2, "h2", 60_000).unwrap();
    assert_eq!(s2.len(), 3, "second member sees the appends at open");

    let t1 = std::thread::spawn(move || {
        let report = s1.enforce_limits(0, 1).unwrap();
        (s1, report)
    });
    let t2 = std::thread::spawn(move || {
        let report = s2.enforce_limits(0, 1).unwrap();
        (s2, report)
    });
    let (_, r1) = t1.join().unwrap();
    let (_, r2) = t2.join().unwrap();
    assert!(
        r1.n_evicted + r2.n_evicted >= 2,
        "the two cold keys were evicted between the racers: {r1:?} / {r2:?}"
    );

    // The survivor is the served key, intact, and the layout reopens.
    let reopened = ShardedStore::open(&dir, 2).unwrap();
    assert_eq!(reopened.len(), 1, "exactly the retained record survives");
    assert_eq!(reopened.get(suites::MV3, &cfg_b).as_deref(), Some(&rec_b));
    let _ = std::fs::remove_dir_all(&dir);
}

/// A crashed holder's shard lease expires and compaction is reclaimed
/// by the surviving member without losing retained records.
#[test]
fn expired_lease_is_reclaimed_for_compaction() {
    let dir = tmp_dir("reclaim");
    let store = ShardedStore::open_fleet(&dir, 1, "alive", 60_000).unwrap();
    let (rec_a, _) = record_for(suites::MM1, 23);
    let (rec_b, cfg_b) = record_for(suites::MV3, 24);
    store.append(rec_a).unwrap();
    store.append(rec_b.clone()).unwrap();
    store.mark_served(&key_of(&rec_b)).unwrap();

    // A "daemon" takes the shard lease and crashes (never releases,
    // never heartbeats) with a short TTL.
    let lease_path = dir.join(LEASES_DIR).join(format!("{}.json", shard_lease_name(0)));
    let crashed = Lease::acquire(&lease_path, "crashed", 150, None).unwrap().unwrap();

    let blocked = store.enforce_limits(0, 1).unwrap();
    assert_eq!(blocked.n_evicted, 0, "live lease blocks the rewrite");
    assert_eq!(blocked.n_skipped_shards, 1);
    assert_eq!(store.len(), 2);

    std::thread::sleep(Duration::from_millis(300));
    let reclaimed = store.enforce_limits(0, 1).unwrap();
    assert_eq!(reclaimed.n_evicted, 1, "expired lease reclaimed, eviction proceeds");
    assert_eq!(reclaimed.n_skipped_shards, 0);
    assert!(!crashed.is_current().unwrap(), "the crashed holder is fenced out");
    assert_eq!(store.get(suites::MV3, &cfg_b).as_deref(), Some(&rec_b), "retained intact");

    let reopened = ShardedStore::open(&dir, 1).unwrap();
    assert_eq!(reopened.len(), 1, "compaction under a reclaimed lease is durable");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Epoch fencing: a write-back guarded by a claim that expired and was
/// reclaimed by another daemon is rejected, and the new owner's
/// write-back goes through.
#[test]
fn stale_claim_write_back_is_rejected() {
    let dir = tmp_dir("fence");
    let store = ShardedStore::open_fleet(&dir, 2, "daemon-a", 60_000).unwrap();
    let (rec, cfg) = record_for(suites::MM1, 25);
    let key = key_of(&rec);

    let table_a = InflightTable::open(&dir, "daemon-a", 120).unwrap();
    let stale = table_a.claim(&key).unwrap().expect("daemon-a claims the search");
    // daemon-a stalls past its TTL (no heartbeat); daemon-b reclaims.
    std::thread::sleep(Duration::from_millis(260));
    let table_b = InflightTable::open(&dir, "daemon-b", 60_000).unwrap();
    let fresh = table_b.claim(&key).unwrap().expect("expired claim reclaimed");
    assert!(fresh.epoch() > stale.epoch());

    // The stalled daemon's late write-back is fenced out…
    assert!(!store.append_claimed(rec.clone(), &stale).unwrap());
    assert!(store.get(suites::MM1, &cfg).is_none(), "rejected write-back left no record");
    assert!(store.is_empty());
    // …while the current owner's goes through.
    assert!(store.append_claimed(rec.clone(), &fresh).unwrap());
    assert_eq!(store.get(suites::MM1, &cfg).as_deref(), Some(&rec));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Per-shard locks end to end (ISSUE 4): with shard B's lock held —
/// standing in for a miss's fleet refresh stalled mid disk read — the
/// full hit path against shard A (per-key fleet refresh, exact
/// lookup, LRU touch) completes, while a request against shard B
/// itself waits for the hold to release.
#[test]
fn hit_on_shard_a_completes_while_shard_b_refresh_is_held() {
    let dir = tmp_dir("shardhold");
    let store = ShardedStore::open_fleet(&dir, 2, "h1", 60_000).unwrap();

    // Find serve keys routing to each of the two shards (seeds change
    // the fingerprint, so the candidate pool is effectively unbounded).
    let mut on_shard: [Option<(Workload, SearchConfig)>; 2] = [None, None];
    'fill: for seed in 0..8u64 {
        for (i, (_, w)) in suites::table2_suite().iter().enumerate() {
            let cfg = quick_search(100 + seed * 31 + i as u64);
            let rec = hand_record(*w, &cfg);
            let shard = store.shard_of(&key_of(&rec));
            if on_shard[shard].is_none() {
                store.append(rec).unwrap();
                on_shard[shard] = Some((*w, cfg));
            }
            if on_shard.iter().all(|s| s.is_some()) {
                break 'fill;
            }
        }
    }
    let (w_a, cfg_a) = on_shard[0].clone().expect("a key routing to shard 0");
    let (w_b, cfg_b) = on_shard[1].clone().expect("a key routing to shard 1");
    let store = Arc::new(store);

    // Shard 1 stalls (lock held across "disk I/O").
    let hold = store.hold_shard(1);

    // The shard-0 hit path runs to completion regardless.
    let (tx, rx) = std::sync::mpsc::channel();
    let s = store.clone();
    std::thread::spawn(move || {
        let key = serve_key(
            &w_a.id(),
            cfg_a.gpu.name(),
            cfg_a.mode.name(),
            &config_fingerprint(&cfg_a),
        );
        s.refresh_key(&key).unwrap();
        let hit = s.get(w_a, &cfg_a).is_some();
        s.mark_served(&key).unwrap();
        tx.send(hit).unwrap();
    });
    assert_eq!(
        rx.recv_timeout(Duration::from_secs(20)),
        Ok(true),
        "the shard-0 hit path must complete while shard 1 is held"
    );

    // A shard-1 lookup waits for the hold, then completes.
    let (tx, rx) = std::sync::mpsc::channel();
    let s = store.clone();
    std::thread::spawn(move || {
        tx.send(s.get(w_b, &cfg_b).is_some()).unwrap();
    });
    assert!(
        rx.recv_timeout(Duration::from_millis(300)).is_err(),
        "a shard-1 lookup must wait behind the held refresh"
    );
    drop(hold);
    assert_eq!(rx.recv_timeout(Duration::from_secs(20)), Ok(true));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Partial fleet telemetry (ISSUE 7): one live daemon + one dead
/// address merges to the live daemon's metrics plus an error entry —
/// the old behavior aborted the whole merge on the first unreachable
/// daemon, blinding the operator to the surviving fleet.
#[test]
fn merged_metrics_survives_a_dead_daemon() {
    let dir = tmp_dir("partial_merge");
    let a = spawn_on(ServeAddr::Unix(dir.join("a.sock")), &dir, quick_search(41));
    let mut ca = ServeClient::connect(&a.addr).unwrap();
    assert!(get_kernel(&mut ca, suites::MM1, None, None).unwrap().enqueued);
    ca.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    let solo = metrics(&mut ca).unwrap();

    // A socket path nothing listens on stands in for a crashed daemon.
    let dead = ServeAddr::Unix(dir.join("dead.sock"));
    let fm = merged_metrics(&[a.addr.clone(), dead.clone()]).unwrap();
    assert_eq!(fm.errors.len(), 1, "exactly the dead daemon errored: {:?}", fm.errors);
    assert!(fm.errors[0].0.contains("dead.sock"), "{:?}", fm.errors);
    assert_eq!(fm.merged.counters, solo.counters, "merge equals the live daemon alone");
    assert_eq!(fm.merged.reply_wall_s, solo.reply_wall_s);

    // Dead-daemon order must not matter either.
    let fm2 = merged_metrics(&[dead.clone(), a.addr.clone()]).unwrap();
    assert_eq!(fm2.errors.len(), 1);
    assert_eq!(fm2.merged.counters, solo.counters);

    // A fleet with NO reachable daemon is still an error.
    assert!(merged_metrics(&[dead]).is_err());

    ca.shutdown().unwrap();
    a.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The tentpole acceptance path (ISSUE 7): a miss duplicated across
/// two daemons yields ONE distributed trace fleet-wide — on the
/// searching daemon it carries the hot-path stages, per-round model
/// telemetry, and the write-back landing; on the peer the SAME id
/// continues as a remote `notify_refresh` span once the announcement
/// is ingested.
#[test]
fn duplicated_miss_yields_one_trace_across_the_fleet() {
    let dir = tmp_dir("trace_chain");
    let mut search = quick_search(43);
    search.fleet.notify_interval_ms = 25;
    search.fleet.poll_interval_ms = 3_600_000;
    let a = spawn_on(ServeAddr::Unix(dir.join("a.sock")), &dir, search.clone());
    let b = spawn_on(ServeAddr::Unix(dir.join("b.sock")), &dir, search);
    let mut ca = ServeClient::connect(&a.addr).unwrap();
    let mut cb = ServeClient::connect(&b.addr).unwrap();

    // The reserving miss adopts the client-chosen trace id; the
    // duplicate (whether it coalesces locally or fleet-wide) must NOT
    // open a second trace.
    let wire_id = "feedc0dedeadbeef";
    let first = ca.get_kernel_traced(suites::MM1, None, None, Some(wire_id)).unwrap();
    assert!(!first.hit && first.enqueued);
    get_kernel(&mut ca, suites::MM1, None, None).unwrap(); // duplicate on A
    get_kernel(&mut cb, suites::MM1, None, None).unwrap(); // duplicate on B
    ca.wait_for_drain(DRAIN_TIMEOUT).unwrap();

    // A: exactly one trace, complete, under the client's id, with the
    // whole story attached.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let trace_a = loop {
        let tr = traces(&mut ca, 0).unwrap();
        if let Some(t) = tr.traces.iter().find(|t| t.complete && !t.remote) {
            assert_eq!(tr.traces.len(), 1, "duplicates opened no extra trace: {tr:?}");
            break t.clone();
        }
        assert!(std::time::Instant::now() < deadline, "A never completed its trace: {tr:?}");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_eq!(trace_a.id.to_hex(), wire_id, "reserving miss adopted the wire trace id");
    assert!(!trace_a.error);
    let names: Vec<&str> = trace_a.spans.iter().map(|s| s.name.as_str()).collect();
    for expected in ["parse", "enqueue", "reply_write", "search_round", "writeback"] {
        assert!(names.contains(&expected), "missing span '{expected}' in {names:?}");
    }
    let rounds: Vec<_> = trace_a.spans.iter().filter(|s| s.name == "search_round").collect();
    assert_eq!(rounds.len(), 3, "one span per search round");
    assert!(rounds.iter().all(|s| s.round.is_some() && s.n_measured.is_some()));
    assert!(rounds.iter().any(|s| s.k.is_some()), "dynamic-k telemetry rode along");
    let wb = trace_a.spans.iter().find(|s| s.name == "writeback").unwrap();
    assert_eq!(wb.note.as_deref(), Some("accepted"));

    // B: the SAME id continues as a completed remote trace whose
    // notify_refresh span names the announcing holder.
    let trace_b = loop {
        let tr = traces(&mut cb, 0).unwrap();
        if let Some(t) = tr.traces.iter().find(|t| t.remote) {
            break t.clone();
        }
        assert!(std::time::Instant::now() < deadline, "B never ingested the trace: {tr:?}");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_eq!(trace_b.id, trace_a.id, "one trace id spans the fleet");
    assert_eq!(trace_b.key, trace_a.key);
    assert!(trace_b.complete);
    let refresh = trace_b.spans.iter().find(|s| s.name == "notify_refresh").unwrap();
    assert!(refresh.note.is_some(), "the span names the announcing holder");

    // And the chain ends in B serving A's record as an exact hit.
    assert!(cb.get_kernel_wait(suites::MM1, None, None, DRAIN_TIMEOUT).unwrap().hit);

    for (mut client, handle) in [(ca, a), (cb, b)] {
        client.shutdown().unwrap();
        handle.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The energy-accounting pin (ISSUE 8): two TCP daemons on one store
/// — A pays the only search's measurement joules, both serve
/// attributed hits, and the fleet-merged ledger is the elementwise
/// union of the members' cells, riding the Prometheus exposition with
/// stable `gpu`/`family` labels.
#[test]
fn fleet_energy_ledger_merges_as_union_over_tcp() {
    let dir = tmp_dir("ledger_union");
    // Freeze the background refresh loops so the only ledger mutations
    // are this test's requests (same setup as the metrics-merge pin).
    let mut search = quick_search(51);
    search.fleet.notify_interval_ms = 3_600_000;
    search.fleet.poll_interval_ms = 3_600_000;
    let a = spawn_on(ServeAddr::Tcp("127.0.0.1:0".to_string()), &dir, search.clone());
    let b = spawn_on(ServeAddr::Tcp("127.0.0.1:0".to_string()), &dir, search);
    let mut ca = ServeClient::connect(&a.addr).unwrap();
    let mut cb = ServeClient::connect(&b.addr).unwrap();

    // A pays the fleet's one search; both daemons then serve hits off
    // the landed record (B ingests it via the on-miss refresh).
    assert!(get_kernel(&mut ca, suites::MM1, None, None).unwrap().enqueued);
    ca.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    for _ in 0..3 {
        assert!(get_kernel(&mut ca, suites::MM1, None, None).unwrap().hit);
    }
    assert!(cb.get_kernel_wait(suites::MM1, None, None, DRAIN_TIMEOUT).unwrap().hit);
    assert!(get_kernel(&mut cb, suites::MM1, None, None).unwrap().hit);

    let ma = metrics(&mut ca).unwrap();
    let mb = metrics(&mut cb).unwrap();
    let (gpu, mm) = (ledger_gpu_index("a100").unwrap(), ledger_family_index("mm"));

    // The searching daemon debited real measurement joules into the
    // record's cell; the peer never searched. Every hit was served off
    // a freshly written record, which carries its baseline — so every
    // hit landed ATTRIBUTED, none in the unattributed column.
    assert_eq!(ma.energy.n_searches(gpu, mm), 1);
    assert!(ma.energy.paid_j(gpu, mm) > 0.0, "{}", ma.energy.paid_j(gpu, mm));
    assert_eq!(ma.energy.n_hits(gpu, mm), 3);
    assert_eq!(mb.energy.n_searches(gpu, mm), 0, "B never searched");
    assert!(mb.energy.n_hits(gpu, mm) >= 2, "{}", mb.energy.n_hits(gpu, mm));
    assert_eq!(ma.energy.total_unattributed() + mb.energy.total_unattributed(), 0);
    assert!(ma.energy.saved_j(gpu, mm) >= 0.0);

    // The fleet merge equals the elementwise union of both ledgers,
    // cell by cell across the full gpu x family grid.
    let fm = merged_metrics(&[a.addr.clone(), b.addr.clone()]).unwrap();
    assert!(fm.errors.is_empty(), "{:?}", fm.errors);
    let merged = &fm.merged.energy;
    for g in 0..LEDGER_GPUS.len() {
        for f in 0..LEDGER_FAMILIES.len() {
            assert_eq!(
                merged.n_hits(g, f),
                ma.energy.n_hits(g, f) + mb.energy.n_hits(g, f),
                "n_hits[{g}][{f}]"
            );
            assert_eq!(
                merged.n_searches(g, f),
                ma.energy.n_searches(g, f) + mb.energy.n_searches(g, f),
                "n_searches[{g}][{f}]"
            );
            let saved = ma.energy.saved_j(g, f) + mb.energy.saved_j(g, f);
            assert!((merged.saved_j(g, f) - saved).abs() < 1e-12, "saved_j[{g}][{f}]");
            let paid = ma.energy.paid_j(g, f) + mb.energy.paid_j(g, f);
            assert!((merged.paid_j(g, f) - paid).abs() < 1e-12, "paid_j[{g}][{f}]");
        }
    }
    assert_eq!(merged.cells().collect::<Vec<_>>(), vec![(gpu, mm)], "one populated cell");
    // Merge commutes, like every other metrics family.
    let mut expect = ma.clone();
    expect.merge(&mb);
    let mut other_order = mb.clone();
    other_order.merge(&ma);
    assert_eq!(merged, &expect.energy);
    assert_eq!(other_order.energy, expect.energy);
    // And the ledger rides the Prometheus exposition with stable
    // labels (nothing emitted for empty cells).
    let prom = fm.merged.to_prometheus();
    assert!(
        prom.contains("ecokernel_energy_saved_joules_total{gpu=\"a100\",family=\"mm\"}"),
        "{prom}"
    );
    assert!(
        prom.contains("ecokernel_energy_paid_joules_total{gpu=\"a100\",family=\"mm\"}"),
        "{prom}"
    );
    assert!(!prom.contains("family=\"unattributed\""), "{prom}");

    for (mut client, handle) in [(ca, a), (cb, b)] {
        client.shutdown().unwrap();
        handle.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fleet health (ISSUE 8): a healthy singleton merges to `ok` with
/// every `[slo]` target present; adding a dead address keeps the merge
/// alive but flips the synthesized `fleet_reachability` target to
/// critical, NAMING the unreachable socket.
#[test]
fn merged_health_survives_a_dead_daemon_and_names_it() {
    let dir = tmp_dir("health_partial");
    let a = spawn_on(ServeAddr::Unix(dir.join("a.sock")), &dir, quick_search(53));
    let mut ca = ServeClient::connect(&a.addr).unwrap();
    assert!(get_kernel(&mut ca, suites::MM1, None, None).unwrap().enqueued);
    ca.wait_for_drain(DRAIN_TIMEOUT).unwrap();

    // Healthy fleet-of-one: the default [slo] targets are lenient and
    // the windows are below min_window, so everything reports ok.
    let solo = merged_health(&[a.addr.clone()]).unwrap();
    assert!(solo.errors.is_empty(), "{:?}", solo.errors);
    assert_eq!(solo.merged.status, HealthStatus::Ok, "{:?}", solo.merged);
    let names: Vec<&str> = solo.merged.targets.iter().map(|t| t.name.as_str()).collect();
    for expected in
        ["p99_reply_wall_s", "hit_rate", "relerr_steady", "backlog", "fleet_reachability"]
    {
        assert!(names.contains(&expected), "missing target '{expected}' in {names:?}");
    }

    // One live daemon + one dead address: the merge survives, goes
    // critical, and the reachability reason names the dead socket.
    let dead = ServeAddr::Unix(dir.join("dead.sock"));
    let fh = merged_health(&[a.addr.clone(), dead.clone()]).unwrap();
    assert_eq!(fh.errors.len(), 1, "{:?}", fh.errors);
    assert_eq!(fh.merged.status, HealthStatus::Critical);
    let reach = fh.merged.targets.iter().find(|t| t.name == "fleet_reachability").unwrap();
    assert_eq!(reach.status, HealthStatus::Critical);
    assert!(reach.reason.contains("dead.sock"), "{}", reach.reason);
    // The survivor's own verdicts stay visible next to the page.
    let hit_rate = fh.merged.targets.iter().find(|t| t.name == "hit_rate").unwrap();
    assert_eq!(hit_rate.status, HealthStatus::Ok);

    assert!(merged_health(&[dead]).is_err(), "a fleet with NO reachable daemon is an error");

    ca.shutdown().unwrap();
    a.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The drift watchdog end to end (ISSUE 8): with the steady-regime
/// relerr ceiling set below what the simulated measurements produce,
/// the watchdog flags the model as drifting, re-searches the hottest
/// stored key within its per-interval budget, and reports all of it
/// through the `health` op.
#[test]
fn drift_watchdog_researches_hottest_key_within_budget() {
    let dir = tmp_dir("drift");
    let mut search = quick_search(57);
    // Any real relerr sample breaches this ceiling, and one sample is
    // window enough — the first watchdog tick after the seed search
    // lands must see the model as drifting.
    search.slo.relerr_ceiling = 1e-9;
    search.slo.min_window = 1;
    search.slo.drift_interval_ms = 300;
    search.slo.drift_budget = 1;
    let t0 = std::time::Instant::now();
    let handle = spawn_on(ServeAddr::Unix(dir.join("eco.sock")), &dir, search);
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    // Seed: one miss pays a search, whose rounds record the steady
    // relerr samples the watchdog judges; the request also heats MM1
    // in the admission sketch, making it the re-search candidate.
    assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().enqueued);
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().hit);

    // The watchdog notices the breach and admits a re-search.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let health = loop {
        let h = health(&mut client).unwrap();
        if h.drift.n_drift_researches >= 1 {
            break h;
        }
        assert!(std::time::Instant::now() < deadline, "watchdog never re-searched: {h:?}");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(health.drift.drifting, "{:?}", health.drift);
    assert_eq!(health.drift.budget, 1);
    assert!(health.drift.relerr_steady_mean > 1e-9, "{:?}", health.drift);
    let relerr = health.targets.iter().find(|t| t.name == "relerr_steady").unwrap();
    assert!(
        matches!(relerr.status, HealthStatus::Warn | HealthStatus::Critical),
        "a drifting model must not report ok: {relerr:?}"
    );
    let worst = health.targets.iter().fold(HealthStatus::Ok, |acc, t| acc.worst(t.status));
    assert_eq!(health.status, worst, "overall status is the worst per-target verdict");

    // Budget: at most one admission per elapsed watchdog interval
    // (the single stored key also serializes re-searches through the
    // pending table, so this bound is far from tight).
    let intervals = t0.elapsed().as_millis() as u64 / 300 + 1;
    assert!(
        health.drift.n_drift_researches <= intervals,
        "{} re-searches in {} intervals",
        health.drift.n_drift_researches,
        intervals
    );
    // The same counter rides the metrics op for dashboards.
    assert!(metrics(&mut client).unwrap().counter("n_drift_researches") >= 1);

    // The re-searched record supersedes in place and keeps serving.
    client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    let hit = get_kernel(&mut client, suites::MM1, None, None).unwrap();
    assert!(hit.hit, "re-search kept the key servable");
    assert_eq!(stats(&mut client).unwrap().n_records, 1, "superseded, not duplicated");

    client.shutdown().unwrap();
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Admission under saturation: with one worker, a one-slot queue and a
/// one-slot backlog, cold keys are shed in favor of hot ones, and the
/// admitted set drains to completion.
#[test]
fn saturated_queue_sheds_cold_keys_and_keeps_hot_ones() {
    let dir = tmp_dir("admission");
    let mut search = quick_search(11);
    // Beefier searches than the other tests: each must stay in flight
    // across the whole request burst below for the slot arithmetic to
    // be deterministic.
    search.population = 256;
    search.m_latency_keep = 16;
    search.rounds = 12;
    search.patience = 0;
    search.serve.queue_cap = 1;
    search.fleet.backlog_cap = 1;
    let handle = spawn_on(ServeAddr::Unix(dir.join("eco.sock")), &dir, search);
    let mut client = ServeClient::connect(&handle.addr).unwrap();

    // k1 -> worker, k2 -> queue, k3 -> backlog: all admitted. The
    // pause lets the (seconds-long) k1 search leave the queue for its
    // worker, so the slot arithmetic below is deterministic.
    assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().enqueued);
    std::thread::sleep(Duration::from_millis(150));
    assert!(get_kernel(&mut client, suites::MM2, None, None).unwrap().enqueued);
    assert!(get_kernel(&mut client, suites::MM3, None, None).unwrap().enqueued);
    // k4 arrives hotter (more recent) than the backlogged k3 under the
    // decayed-rate sketch: it displaces k3, which is shed.
    assert!(get_kernel(&mut client, suites::MM4, None, None).unwrap().enqueued);
    // Re-requesting k3 heats it past k4: k3 displaces k4 back out.
    assert!(get_kernel(&mut client, suites::MM3, None, None).unwrap().enqueued);

    let s = stats(&mut client).unwrap();
    assert_eq!(s.n_shed, 2, "two displacement sheds under saturation");
    assert_eq!(s.backlog_len, 1, "one key heat-queued behind the saturated queue");

    // The admitted set (MM1, MM2, MM3) drains; shed keys never ran.
    let drained = client.wait_for_drain(DRAIN_TIMEOUT).unwrap();
    assert_eq!(drained.n_searches_done, 3);
    assert_eq!(drained.n_enqueued, 3, "admissions minus sheds");
    assert!(get_kernel(&mut client, suites::MM1, None, None).unwrap().hit);
    assert!(get_kernel(&mut client, suites::MM3, None, None).unwrap().hit, "hot key was kept");

    client.shutdown().unwrap();
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
