//! Integration: every paper experiment regenerates at quick effort and
//! the paper's qualitative claims hold (DESIGN.md §7's "what counts as
//! reproduced" list).

use ecokernel::experiments::{self, Effort};

#[test]
fn table2_ours_wins_energy_without_losing_latency() {
    let t = experiments::table2(Effort::Paper);
    assert_eq!(t.rows.len(), 11);
    for r in &t.rows {
        assert!(
            r.energy_reduction_pct() > -3.0,
            "{}: energy regressed by {:.1}%",
            r.name,
            -r.energy_reduction_pct()
        );
        assert!(
            r.latency_increase_pct() < 25.0,
            "{}: latency blew up {:.1}%",
            r.name,
            r.latency_increase_pct()
        );
    }
    // Average reduction in the paper's band (several to twenties %).
    let avg = t.avg_energy_reduction_pct();
    assert!(avg > 1.0, "avg reduction {avg:.2}% too small");
    // At least one operator shows a double-digit reduction (MM1-class).
    assert!(
        t.rows.iter().any(|r| r.energy_reduction_pct() > 8.0),
        "no big-win operator"
    );
}

#[test]
fn table3_holds_on_rtx4090() {
    let t = experiments::table3(Effort::Paper);
    assert_eq!(t.rows.len(), 3);
    for r in &t.rows {
        assert!(r.energy_reduction_pct() > -3.0, "{}", r.name);
    }
    assert!(t.avg_energy_reduction_pct() > 0.5);
}

#[test]
fn table4_cublas_is_faster_but_not_more_efficient_on_mm() {
    let t = experiments::table4(Effort::Paper);
    assert_eq!(t.rows.len(), 4);
    for (name, cublas, ours) in &t.rows {
        // cuBLAS keeps its latency crown (or ties): a tuned vendor
        // kernel should not lose by much.
        assert!(
            cublas.latency_s <= ours.latency_s * 1.15,
            "{name}: cublas latency {} vs ours {}",
            cublas.latency_s,
            ours.latency_s
        );
    }
    // On the compute-bound MM shapes, ours wins (or ties) energy.
    for (name, cublas, ours) in t.rows.iter().take(2) {
        assert!(
            ours.energy_j <= cublas.energy_j * 1.05,
            "{name}: ours {} mJ vs cublas {} mJ",
            ours.energy_j * 1e3,
            cublas.energy_j * 1e3
        );
    }
}

#[test]
fn fig2_ours_saves_energy_at_similar_latency() {
    let f = experiments::fig2(Effort::Quick);
    assert!(f.scatter.len() >= 100);
    let (alat, aenergy) = f.ansor;
    let (olat, oenergy) = f.ours;
    assert!(oenergy <= aenergy * 1.02, "ours {oenergy} vs ansor {aenergy}");
    assert!(olat <= alat * 1.30, "latency class: {olat} vs {alat}");
    // The scatter itself must show energy spread at similar latency.
    assert!(f.summary().contains("Fig 2"));
}

#[test]
fn fig3_latency_power_inverse() {
    let f = experiments::fig3(Effort::Quick);
    assert!(f.pearson_r < -0.3, "r = {}", f.pearson_r);
}

#[test]
fn fig4_cost_model_ranks_energy_well() {
    let f = experiments::fig4(Effort::Quick);
    assert_eq!(f.panels.len(), 3);
    for p in &f.panels {
        assert!(p.spearman > 0.75, "{}: rho = {}", p.name, p.spearman);
        assert!(p.r2 > 0.5, "{}: R2 = {}", p.name, p.r2);
        assert!(!p.points.is_empty());
    }
}

#[test]
fn fig5_cost_model_speeds_up_search() {
    let f = experiments::fig5(Effort::Quick);
    for r in &f.rows {
        assert!(r.speedup() > 1.0, "{}: {}", r.name, r.speedup());
        assert!(r.nvml_measurements_cost_model < r.nvml_measurements_nvml_only);
    }
}

#[test]
fn run_by_id_writes_result_files() {
    let dir = std::env::temp_dir().join(format!("ecokernel_results_{}", std::process::id()));
    std::env::set_var("ECOKERNEL_RESULTS", &dir);
    let text = experiments::run_by_id("table1", Effort::Quick).expect("table1");
    assert!(text.contains("Ours"));
    assert!(dir.join("table1.txt").exists());
    let fig3 = experiments::run_by_id("fig3", Effort::Quick).expect("fig3");
    assert!(fig3.contains("Pearson"));
    assert!(dir.join("fig3.csv").exists());
    assert!(experiments::run_by_id("nope", Effort::Quick).is_err());
    std::env::remove_var("ECOKERNEL_RESULTS");
    let _ = std::fs::remove_dir_all(&dir);
}
